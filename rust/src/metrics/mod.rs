//! Serving metrics substrate: counters, gauges, latency histograms with
//! streaming percentiles — shared by the coordinator, the decode
//! scheduler, and the bench harness.
//!
//! Metrics are **labeled families**: `registry.counter_with("serve_tokens_emitted",
//! &[("variant", "tiny/dobi_40")])` keys one child per label set, and the
//! same family sums across children for aggregate views
//! ([`Registry::family_total`]).  Two text renderings exist — the
//! historical plain dump ([`Registry::render`]) and a Prometheus-style
//! exposition ([`Registry::render_prom`]) for scrapers.

pub mod names;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use crate::mathx::{summarize, Stats};

/// Process-wide count of poisoned-lock recoveries, rendered as the
/// [`names::LOCK_POISONED`] counter family.
static LOCK_POISONED_RECOVERIES: AtomicU64 = AtomicU64::new(0);

/// Acquire `m`, recovering the inner data if a previous holder panicked.
///
/// The serve request path must not die because some other thread poisoned a
/// metrics/trace/registry mutex: the protected state (counter maps, trace
/// slots, variant tables) stays structurally valid under panic-at-any-point,
/// so recovery is safe. Each recovery bumps [`lock_poisoned_total`] — a
/// nonzero value in a scrape means a panic happened somewhere and was
/// absorbed, which is a bug report, not business as usual.
pub fn lock_or_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => {
            LOCK_POISONED_RECOVERIES.fetch_add(1, Ordering::Relaxed);
            poisoned.into_inner()
        }
    }
}

/// How many times [`lock_or_recover`] found a poisoned mutex.
pub fn lock_poisoned_total() -> u64 {
    LOCK_POISONED_RECOVERIES.load(Ordering::Relaxed)
}

#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time level (queue depth, active sessions) — unlike a
/// [`Counter`] it moves both ways.
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, dv: i64) {
        self.0.fetch_add(dv, Ordering::Relaxed);
    }

    pub fn sub(&self, dv: i64) {
        self.0.fetch_sub(dv, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Sample reservoir + running sum, guarded by one mutex (both are
/// touched together on every observation anyway).
struct Reservoir {
    vals: Vec<f64>,
    /// xorshift64 state for the overwrite index — NOT derived from the
    /// observed value: value-deterministic indices made identical
    /// latencies collide into one slot, skewing long-run percentiles.
    rng: u64,
    sum: f64,
}

/// Latency histogram: fixed log-spaced buckets (1us .. ~100s) plus a
/// bounded uniform reservoir of raw samples for exact percentiles in
/// reports.  The reservoir is Algorithm R: sample `n` survives with
/// probability `cap/n`, driven by an atomic observation counter and a
/// xorshift index (never by the sample's value).
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    bounds_us: Vec<u64>,
    /// Total observations of either kind (the atomic sample counter the
    /// reservoir's survival probability derives from).
    total: AtomicU64,
    res: Mutex<Reservoir>,
    cap: usize,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new(4096)
    }
}

impl Histogram {
    pub fn new(cap: usize) -> Self {
        let mut bounds_us = Vec::new();
        let mut b = 1u64;
        while b < 100_000_000 {
            bounds_us.push(b);
            b = (b as f64 * 1.6).ceil() as u64;
        }
        let buckets = (0..=bounds_us.len()).map(|_| AtomicU64::new(0)).collect();
        Histogram {
            buckets,
            bounds_us,
            total: AtomicU64::new(0),
            res: Mutex::new(Reservoir { vals: Vec::new(), rng: 0x9E37_79B9_7F4A_7C15, sum: 0.0 }),
            cap,
        }
    }

    /// Record a duration: log-bucket counter + reservoir sample.
    pub fn observe(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = self.bounds_us.partition_point(|&b| b < us);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.reservoir_put(d.as_secs_f64());
    }

    /// Record a dimensionless value (a fused batch size, an acceptance
    /// rate) — reservoir/percentile machinery only.  These do NOT
    /// round-trip through the latency buckets: the buckets are
    /// microsecond-shaped, and the old conversion silently saturated
    /// negative values to bucket 0.  Name such histograms `*_size` or
    /// `*_rate` so the renderers omit the seconds unit.
    pub fn observe_value(&self, v: f64) {
        self.reservoir_put(v);
    }

    fn reservoir_put(&self, v: f64) {
        // the +1 makes `seen` the 1-based count INCLUDING this sample —
        // the denominator Algorithm R's cap/seen survival needs
        let seen = self.total.fetch_add(1, Ordering::Relaxed) + 1;
        let mut r = lock_or_recover(&self.res);
        r.sum += v;
        if r.vals.len() < self.cap {
            r.vals.push(v);
        } else {
            // xorshift64 step, index uniform in [0, seen): the sample
            // replaces a random resident slot with probability cap/seen
            r.rng ^= r.rng << 13;
            r.rng ^= r.rng >> 7;
            r.rng ^= r.rng << 17;
            let j = (r.rng % seen) as usize;
            if j < self.cap {
                r.vals[j] = v;
            }
        }
    }

    /// Observations recorded (both [`Self::observe`] and
    /// [`Self::observe_value`]), unbounded by the reservoir cap.
    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Running sum of every observed value (seconds for durations).
    pub fn sum(&self) -> f64 {
        lock_or_recover(&self.res).sum
    }

    pub fn stats(&self) -> Stats {
        summarize(&lock_or_recover(&self.res).vals)
    }

    #[cfg(test)]
    fn reservoir_len(&self) -> usize {
        lock_or_recover(&self.res).vals.len()
    }
}

/// `name` or `name{k="v",...}` — the registry's storage key doubles as
/// the render form for both text formats.
fn keyed(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let body: Vec<String> = labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
    format!("{name}{{{}}}", body.join(","))
}

/// Family name of a stored key (`a{b="c"}` → `a`).
fn family_of(key: &str) -> &str {
    key.split('{').next().unwrap_or(key)
}

/// Does `key` belong to `family` (exact name match, any label set)?
fn in_family(key: &str, family: &str) -> bool {
    family_of(key) == family
}

/// Split a stored key into (family, label-body-with-braces-or-empty).
fn split_key(key: &str) -> (&str, &str) {
    match key.find('{') {
        Some(i) => (&key[..i], &key[i..]),
        None => (key, ""),
    }
}

fn is_dimensionless(family: &str) -> bool {
    family.ends_with("_size") || family.ends_with("_rate")
}

/// Named registry the engine exposes (`{"op":"metrics"}`, the serve
/// status line, and the bench harness).
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, std::sync::Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        self.counter_with(name, &[])
    }

    /// Labeled counter child: one instance per `(name, labels)` key.
    pub fn counter_with(&self, name: &str, labels: &[(&str, &str)]) -> std::sync::Arc<Counter> {
        lock_or_recover(&self.counters)
            .entry(keyed(name, labels))
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> std::sync::Arc<Gauge> {
        self.gauge_with(name, &[])
    }

    pub fn gauge_with(&self, name: &str, labels: &[(&str, &str)]) -> std::sync::Arc<Gauge> {
        lock_or_recover(&self.gauges)
            .entry(keyed(name, labels))
            .or_default()
            .clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histogram_with(name, &[])
    }

    pub fn histogram_with(&self, name: &str,
                          labels: &[(&str, &str)]) -> std::sync::Arc<Histogram> {
        lock_or_recover(&self.histograms)
            .entry(keyed(name, labels))
            .or_insert_with(|| std::sync::Arc::new(Histogram::default()))
            .clone()
    }

    /// Sum of a counter family across every label set — the aggregate
    /// the pre-label callers (status lines, `ServeStats`) read.
    pub fn family_total(&self, name: &str) -> u64 {
        lock_or_recover(&self.counters)
            .iter()
            .filter(|(k, _)| in_family(k, name))
            .map(|(_, c)| c.get())
            .sum()
    }

    /// Plain-text dump: `name{labels} value` per counter/gauge child,
    /// `name{labels} count=… mean=… p50=…` per histogram.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, c) in lock_or_recover(&self.counters).iter() {
            out.push_str(&format!("{k} {}\n", c.get()));
        }
        // synthesized from the process-wide recovery counter — there is no
        // Registry child to iterate
        out.push_str(&format!("{} {}\n", names::LOCK_POISONED, lock_poisoned_total()));
        for (k, g) in lock_or_recover(&self.gauges).iter() {
            out.push_str(&format!("{k} {}\n", g.get()));
        }
        for (k, h) in lock_or_recover(&self.histograms).iter() {
            let s = h.stats();
            // dimensionless histograms (observe_value: `*_size` batch
            // sizes, `*_rate` ratios) get no seconds label
            let u = if is_dimensionless(family_of(k)) { "" } else { "s" };
            out.push_str(&format!(
                "{k} count={} mean={:.6}{u} p50={:.6}{u} p95={:.6}{u} p99={:.6}{u}\n",
                h.count(), s.mean, s.p50, s.p95, s.p99
            ));
        }
        out
    }

    /// Prometheus-style text exposition: `# TYPE` headers per family,
    /// one sample line per labeled child; histograms render as summaries
    /// (`quantile` labels + `_sum`/`_count`).
    pub fn render_prom(&self) -> String {
        let mut out = String::new();
        let mut last_family = String::new();
        let mut type_line = |out: &mut String, family: &str, kind: &str| {
            if family != last_family {
                out.push_str(&format!("# TYPE {family} {kind}\n"));
                last_family = family.to_string();
            }
        };
        for (k, c) in lock_or_recover(&self.counters).iter() {
            type_line(&mut out, family_of(k), "counter");
            out.push_str(&format!("{k} {}\n", c.get()));
        }
        type_line(&mut out, names::LOCK_POISONED, "counter");
        out.push_str(&format!("{} {}\n", names::LOCK_POISONED, lock_poisoned_total()));
        for (k, g) in lock_or_recover(&self.gauges).iter() {
            type_line(&mut out, family_of(k), "gauge");
            out.push_str(&format!("{k} {}\n", g.get()));
        }
        for (k, h) in lock_or_recover(&self.histograms).iter() {
            let (family, labels) = split_key(k);
            type_line(&mut out, family, "summary");
            let s = h.stats();
            // splice the quantile label into the existing label set
            let q = |quantile: &str| -> String {
                if labels.is_empty() {
                    format!("{family}{{quantile=\"{quantile}\"}}")
                } else {
                    let inner = &labels[1..labels.len() - 1];
                    format!("{family}{{{inner},quantile=\"{quantile}\"}}")
                }
            };
            out.push_str(&format!("{} {:.9}\n", q("0.5"), s.p50));
            out.push_str(&format!("{} {:.9}\n", q("0.95"), s.p95));
            out.push_str(&format!("{} {:.9}\n", q("0.99"), s.p99));
            out.push_str(&format!("{family}_sum{labels} {:.9}\n", h.sum()));
            out.push_str(&format!("{family}_count{labels} {}\n", h.count()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lock_or_recover_recovers_poisoned_mutex() {
        let m = std::sync::Arc::new(Mutex::new(7i32));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the mutex");
        })
        .join();
        assert!(m.is_poisoned());
        let before = lock_poisoned_total();
        assert_eq!(*lock_or_recover(&m), 7, "inner data recovered intact");
        assert!(lock_poisoned_total() > before, "recovery counted");
        let text = Registry::default().render();
        assert!(text.contains(names::LOCK_POISONED), "{text}");
    }

    #[test]
    fn counter_concurrent() {
        let c = std::sync::Arc::new(Counter::default());
        let mut hs = Vec::new();
        for _ in 0..4 {
            let c2 = c.clone();
            hs.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c2.inc();
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 4000);
    }

    #[test]
    fn histogram_percentiles() {
        let h = Histogram::default();
        for i in 1..=100 {
            h.observe(Duration::from_millis(i));
        }
        let s = h.stats();
        assert_eq!(h.count(), 100);
        assert!((s.p50 - 0.05).abs() < 0.01);
        assert!(s.p99 >= 0.09);
    }

    #[test]
    fn registry_same_instance() {
        let r = Registry::default();
        r.counter("a").inc();
        r.counter("a").inc();
        assert_eq!(r.counter("a").get(), 2);
        let text = r.render();
        assert!(text.contains("a 2"));
    }

    #[test]
    fn gauge_moves_both_ways() {
        let r = Registry::default();
        let g = r.gauge("active");
        g.add(3);
        g.sub(1);
        assert_eq!(r.gauge("active").get(), 2);
        g.set(-4);
        assert_eq!(g.get(), -4);
        assert!(r.render().contains("active -4"));
    }

    #[test]
    fn histogram_observes_raw_values() {
        let h = Histogram::default();
        for v in [1.0f64, 2.0, 3.0, 4.0] {
            h.observe_value(v);
        }
        let s = h.stats();
        assert_eq!(h.count(), 4);
        assert!((s.mean - 2.5).abs() < 1e-9);
        assert!(s.p50 >= 2.0 && s.p50 <= 3.0);
        assert!((h.sum() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_reservoir_bounded() {
        let h = Histogram::new(16);
        for i in 0..1000 {
            h.observe(Duration::from_micros(i));
        }
        assert!(h.reservoir_len() <= 16);
        assert_eq!(h.count(), 1000);
    }

    /// The old overwrite index was `us * 2654435761 % cap` — a pure
    /// function of the value, so identical latencies all landed in ONE
    /// slot and a long steady-state run collapsed the reservoir to two
    /// distinct values.  Algorithm R keeps a uniform sample instead.
    #[test]
    fn reservoir_not_value_deterministic() {
        let h = Histogram::new(64);
        // steady state: many observations of the SAME value, then a
        // late minority of a different value
        for _ in 0..2000 {
            h.observe(Duration::from_micros(500));
        }
        for _ in 0..2000 {
            h.observe(Duration::from_micros(900));
        }
        let r = h.res.lock().unwrap();
        let n_late = r.vals.iter().filter(|v| (**v - 900e-6).abs() < 1e-9).count();
        drop(r);
        // uniform reservoir over a 50/50 stream: the late value holds
        // roughly half the slots (the deterministic index held exactly 1
        // slot per distinct value). 8 of 64 is > 5 sigma below fair.
        assert!(n_late >= 8, "late value underrepresented: {n_late}/64 slots");
        assert!(n_late <= 56, "late value overrepresented: {n_late}/64 slots");
        assert_eq!(h.count(), 4000);
    }

    /// Negative dimensionless values used to saturate to latency bucket
    /// 0 via `(v * 1e6) as u64`; they must survive intact now.
    #[test]
    fn observe_value_handles_negatives_without_bucket_roundtrip() {
        let h = Histogram::new(16);
        for v in [-2.0f64, -1.0, 1.0, 2.0] {
            h.observe_value(v);
        }
        assert_eq!(h.count(), 4);
        let s = h.stats();
        assert!((s.mean - 0.0).abs() < 1e-9, "negatives averaged in: {}", s.mean);
        assert!((h.sum() - 0.0).abs() < 1e-9);
        // latency buckets untouched: dimensionless values no longer
        // masquerade as microsecond durations
        assert_eq!(h.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum::<u64>(), 0);
    }

    #[test]
    fn labeled_children_are_distinct_and_family_sums() {
        let r = Registry::default();
        r.counter_with("served", &[("variant", "a")]).add(3);
        r.counter_with("served", &[("variant", "b")]).add(4);
        r.counter_with("served_other", &[("variant", "c")]).add(100);
        assert_eq!(r.counter_with("served", &[("variant", "a")]).get(), 3);
        assert_eq!(r.family_total("served"), 7, "family sums across label sets");
        assert_eq!(r.family_total("served_other"), 100);
        let text = r.render();
        assert!(text.contains("served{variant=\"a\"} 3"), "{text}");
        assert!(text.contains("served{variant=\"b\"} 4"), "{text}");
    }

    #[test]
    fn prom_exposition_renders_types_labels_and_summaries() {
        let r = Registry::default();
        r.counter_with("reqs", &[("variant", "a"), ("reason", "stop")]).inc();
        r.gauge("depth").set(5);
        let h = r.histogram_with("lat_seconds", &[("variant", "a")]);
        h.observe(Duration::from_millis(10));
        h.observe(Duration::from_millis(20));
        let p = r.render_prom();
        assert!(p.contains("# TYPE reqs counter"), "{p}");
        assert!(p.contains("reqs{variant=\"a\",reason=\"stop\"} 1"), "{p}");
        assert!(p.contains("# TYPE depth gauge"), "{p}");
        assert!(p.contains("depth 5"), "{p}");
        assert!(p.contains("# TYPE lat_seconds summary"), "{p}");
        assert!(p.contains("lat_seconds{variant=\"a\",quantile=\"0.5\"}"), "{p}");
        assert!(p.contains("lat_seconds_count{variant=\"a\"} 2"), "{p}");
        assert!(p.contains("lat_seconds_sum{variant=\"a\"}"), "{p}");
        // unlabeled histogram quantiles still render valid label bodies
        r.histogram("plain_seconds").observe(Duration::from_millis(1));
        let p = r.render_prom();
        assert!(p.contains("plain_seconds{quantile=\"0.5\"}"), "{p}");
        assert!(p.contains("plain_seconds_count 1"), "{p}");
    }
}
