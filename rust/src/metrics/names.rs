//! The single source of truth for `serve_*` and `compress_*` metric
//! family names.
//!
//! Every family the serve stack or the compress pipeline emits is declared
//! here once; code sites reference these constants, the README family
//! tables document the same set, and `dobi lint`'s `metric-drift` rule
//! fails the build if any of the three drifts (a bare `"serve_…"` or
//! `"compress_…"` literal elsewhere in `rust/src` is a deny-level
//! finding). `scripts/serve_smoke.py` parses this file and asserts the
//! live `{"op":"metrics"}` output stays within this vocabulary.

/// Sessions admitted by the scheduler, labeled by `variant`.
pub const SESSIONS_OPENED: &str = "serve_sessions_opened";
/// Sessions retired, labeled by `variant` and terminal `reason`.
pub const SESSIONS_FINISHED: &str = "serve_sessions_finished";
/// Decoded tokens streamed to clients, labeled by `variant`.
pub const TOKENS_EMITTED: &str = "serve_tokens_emitted";
/// Gauge: requests parked in the admission queue.
pub const QUEUE_DEPTH: &str = "serve_queue_depth";
/// Gauge: sessions currently holding KV slots.
pub const ACTIVE_SESSIONS: &str = "serve_active_sessions";
/// Gauge: bytes pinned by resident KV caches.
pub const KV_BYTES: &str = "serve_kv_bytes";
/// Prefill latency histogram (seconds), labeled by `variant`.
pub const PREFILL_SECONDS: &str = "serve_prefill_seconds";
/// Per-step decode latency histogram (seconds), labeled by `variant`.
pub const STEP_SECONDS: &str = "serve_step_seconds";
/// Dimensionless histogram of fused-batch sizes.
pub const FUSED_BATCH_SIZE: &str = "serve_fused_batch_size";
/// Hot swaps that installed a new variant, labeled by `variant`.
pub const SWAP_APPLIED: &str = "serve_swap_applied";
/// Hot swaps rejected (unknown variant, hash mismatch), labeled by `variant`.
pub const SWAP_FAILED: &str = "serve_swap_failed";
/// Gauge: sessions still pinned to a superseded variant.
pub const SWAP_DRAINING_SESSIONS: &str = "serve_swap_draining_sessions";
/// Superseded variants whose last session drained and were released.
pub const SWAP_RELEASES_GCED: &str = "serve_swap_releases_gced";
/// Speculative tokens proposed by the draft variant, labeled by `variant`.
pub const SPEC_PROPOSED: &str = "serve_spec_proposed";
/// Speculative tokens accepted by the verifier, labeled by `variant`.
pub const SPEC_ACCEPTED: &str = "serve_spec_accepted";
/// Dimensionless histogram of per-round speculative acceptance rates.
pub const SPEC_ACCEPT_RATE: &str = "serve_spec_accept_rate";
/// Gauge: microseconds spent drafting in the last speculative round.
pub const SPEC_DRAFT_US: &str = "serve_spec_draft_us";
/// Gauge: microseconds spent verifying in the last speculative round.
pub const SPEC_VERIFY_US: &str = "serve_spec_verify_us";
/// Mutexes found poisoned and recovered by [`super::lock_or_recover`].
pub const LOCK_POISONED: &str = "serve_lock_poisoned";

/// Compression targets inventoried this run, labeled by `variant`.
pub const COMPRESS_TARGETS: &str = "compress_targets";
/// Per-phase wall-clock histogram (seconds), labeled by `phase`.
pub const COMPRESS_PHASE_SECONDS: &str = "compress_phase_seconds";
/// Jacobi sweeps spent decomposing one target, labeled by `target`.
pub const COMPRESS_SVD_SWEEPS: &str = "compress_svd_sweeps";
/// Gauge: rank kept for one target after allocation, labeled by `target`.
pub const COMPRESS_RANK_KEPT: &str = "compress_rank_kept";
/// Dimensionless histogram of per-target whitened tail-energy fractions.
pub const COMPRESS_TAIL_ENERGY_RATE: &str = "compress_tail_energy_rate";
/// Learned-alloc optimizer iterations run, labeled by `variant`.
pub const COMPRESS_TRAIN_ITERS: &str = "compress_train_iters";
