//! Continuous-batching decode scheduler: the stateful replacement for the
//! submit-per-token sliding-window loop.
//!
//! Loaded weights live in a [`VariantRegistry`] of `Arc`-held
//! [`ModelRelease`]s (weights are shared across sessions; per-session
//! state is just a KV cache plus the release `Arc` it decodes against),
//! and one scheduler thread runs a tick loop:
//!
//! ```text
//!  clients ──open()──► waiting (DynamicBatcher, FIFO-fair per variant)
//!                          │ admit while slots free   ◄── evictions free slots
//!                          ▼
//!                  active sessions ── each tick: step() every session
//!                          │            grouped by variant, one token each
//!                          ▼
//!                  GenEvent stream per session (Token / Done / Error)
//! ```
//!
//! New sessions are admitted *between ticks* — mid-flight of everyone
//! else's decode (continuous batching) — and evicted the moment they hit
//! their stop token, `max_tokens`, or KV capacity, so a long generation
//! never blocks short ones behind it.  Each tick's live sessions are
//! grouped by variant and stepped through ONE fused batched trunk walk
//! ([`crate::lowrank::FactorizedModel::forward_kv_multi`]) — every weight
//! tile dequantizes once per tick instead of once per session, which is
//! where low-rank factors' weight-bandwidth advantage actually cashes out
//! under concurrent load.  The fused step is bit-identical to serial
//! stepping (greedy streams cannot tell how many neighbors they shared a
//! tick with).  Queue depth, active sessions, resident KV bytes, fused
//! batch sizes, and per-phase latencies are exported through
//! [`crate::metrics`].

use std::path::PathBuf;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::config::{Manifest, ServeConfig};
use crate::coordinator::batcher::{Batchable, DynamicBatcher};
use crate::coordinator::request::SubmitError;
use crate::json::Json;
use crate::lowrank::{set_decode_threads, FactorizedModel};
use crate::mathx::{sample_logits, XorShift};
use crate::metrics::{lock_or_recover, names, Counter, Registry};
use crate::trace::{export_chrome, phases, RequestTiming, TraceBuffer};

use super::registry::{load_release, ModelRelease, VariantRegistry, VariantStatus};
use super::session::DecodeSession;
use super::spec::{SpecDecoder, SpecParams};

/// Why a session's stream ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Emitted the requested `max_tokens`.
    MaxTokens,
    /// Sampled the client's stop token.
    Stop,
    /// KV capacity exhausted before `max_tokens`.
    Length,
}

impl FinishReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::Stop => "stop",
            FinishReason::Length => "length",
        }
    }
}

/// One event on a session's stream.  `Token`s arrive in `index` order;
/// exactly one `Done` or `Error` terminates the stream.
#[derive(Debug, Clone)]
pub enum GenEvent {
    Token { index: usize, token: i32 },
    Done { n_tokens: usize, reason: FinishReason, timing: RequestTiming },
    Error(String),
}

/// A client's request to open a decode session.
pub struct SessionRequest {
    pub variant: String,
    pub prompt: Vec<i32>,
    /// Image features for VLM variants (consumed at prefill).
    pub image: Option<Vec<f32>>,
    pub max_tokens: usize,
    pub temperature: f32,
    pub seed: u64,
    /// Optional EOS: sampling this token ends the stream (it IS emitted).
    pub stop_token: Option<i32>,
    /// Speculative decode: a compressed draft variant proposes `k` tokens
    /// per tick, the session's own variant verifies them in one batched
    /// multi-row step.  Greedy-only (temperature must be 0); output is
    /// bit-identical to the plain path by construction.
    pub spec: Option<SpecParams>,
    /// Where the scheduler delivers this session's [`GenEvent`]s.
    pub events: mpsc::Sender<GenEvent>,
}

/// Queued request + admission timestamp (FIFO fairness key).
struct Pending {
    req: SessionRequest,
    enqueued: Instant,
}

impl Batchable for Pending {
    fn group(&self) -> (&str, usize) {
        // decode sessions have heterogeneous lengths by design: the
        // batcher's (variant, seq) key collapses to variant-only
        (&self.req.variant, 0)
    }

    fn enqueued(&self) -> Instant {
        self.enqueued
    }
}

enum Cmd {
    Open(Pending),
    Stop,
}

/// Aggregate counters for the status line / tests.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub active_sessions: i64,
    pub queue_depth: i64,
    pub sessions_opened: u64,
    pub sessions_finished: u64,
    pub tokens_emitted: u64,
    /// Hot swaps applied since start (`{"op":"swap"}` successes).
    pub swaps: u64,
    /// In-flight sessions still decoding on superseded releases.
    pub draining_sessions: i64,
}

struct ServeShared {
    metrics: Registry,
    /// The live variant table — admission reads it, swaps write it, the
    /// scheduler sweeps it after each tick's evictions.
    registry: Mutex<VariantRegistry>,
    /// Request-lifecycle span ring (`{"op":"trace"}` drains it); sized
    /// by `ServeConfig::trace_buffer`, 0 = inert.
    trace: Arc<TraceBuffer>,
}

/// Handle to the running scheduler.  Cloneable across client threads via
/// `Arc`; dropping the last handle shuts the scheduler down.
pub struct ServeRuntime {
    tx: mpsc::Sender<Cmd>,
    shared: Arc<ServeShared>,
    /// Artifacts dir swaps reload the manifest + stores from.
    artifacts: PathBuf,
    cfg: ServeConfig,
    join: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl ServeRuntime {
    /// Load `variant_ids` from `artifacts` as native [`FactorizedModel`]s
    /// (content-hash-verified against the manifest's provenance pins) on
    /// the scheduler thread, install them as generation-1 releases, and
    /// start ticking.  Blocks until loading finished so `open()` never
    /// races a cold model.  Variants that
    /// cannot serve incrementally (pruned stores, VLA heads, missing
    /// weights) are skipped with a warning — the caller keeps them on its
    /// fallback path via [`Self::variants`]; only a manifest that yields
    /// NO servable variant is an error.
    pub fn start(artifacts: PathBuf, variant_ids: &[String],
                 cfg: ServeConfig) -> Result<ServeRuntime> {
        anyhow::ensure!(!variant_ids.is_empty(), "no variants to serve");
        anyhow::ensure!(cfg.max_sessions >= 1, "max_sessions must be >= 1");
        anyhow::ensure!(cfg.kv_capacity >= 2, "kv_capacity {} too small", cfg.kv_capacity);
        let shared = Arc::new(ServeShared {
            metrics: Registry::default(),
            registry: Mutex::new(VariantRegistry::default()),
            trace: Arc::new(TraceBuffer::new(cfg.trace_buffer)),
        });
        let (tx, rx) = mpsc::channel::<Cmd>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<Vec<String>>>();
        let ids: Vec<String> = variant_ids.to_vec();
        let dir = artifacts.clone();
        let shared2 = shared.clone();
        let cfg2 = cfg.clone();
        let join = std::thread::Builder::new()
            .name("dobi-decode-scheduler".into())
            .spawn(move || {
                // Load every variant (content hashes verified against the
                // manifest's provenance pins) BEFORE installing anything:
                // a partially-populated registry never becomes visible.
                let load = (|| -> Result<Vec<(String, super::registry::LoadedVariant)>> {
                    let manifest = Manifest::load(&dir)?;
                    let mut loads = Vec::new();
                    let mut errors = Vec::new();
                    for id in &ids {
                        match load_release(&manifest, id) {
                            Ok(l) => loads.push((id.clone(), l)),
                            Err(e) => {
                                eprintln!("[serve] `{id}` not incrementally servable \
                                           ({e:#}); leaving it on the fallback path");
                                errors.push(format!("{id}: {e:#}"));
                            }
                        }
                    }
                    anyhow::ensure!(!loads.is_empty(),
                                    "no variant is incrementally servable: {}",
                                    errors.join("; "));
                    Ok(loads)
                })();
                match load {
                    Ok(loads) => {
                        let served: Vec<String> =
                            loads.iter().map(|(id, _)| id.clone()).collect();
                        {
                            let mut reg = lock_or_recover(&shared2.registry);
                            for (id, l) in loads {
                                reg.install(&id, l);
                            }
                        }
                        let _ = ready_tx.send(Ok(served));
                        scheduler_main(cfg2, rx, shared2);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                    }
                }
            })?;
        ready_rx.recv().map_err(|_| anyhow!("scheduler died during load"))??;
        Ok(ServeRuntime { tx, shared, artifacts, cfg, join: Mutex::new(Some(join)) })
    }

    /// Variants this runtime decodes — a snapshot of the registry's keys
    /// (the servable subset of what [`Self::start`] was asked for, plus
    /// anything a later swap introduced).
    pub fn variants(&self) -> Vec<String> {
        lock_or_recover(&self.shared.registry).variants()
    }

    /// Hot-swap `variant` to whatever its manifest entry currently points
    /// at on disk: reload the manifest, load + hash-verify the store (on
    /// the CALLER's thread — the scheduler keeps ticking throughout), and
    /// install the release as the variant's new generation.  In-flight
    /// sessions drain on the old release; new admissions decode the new
    /// one from the moment this returns.  On any error the registry is
    /// untouched and the old generation keeps serving.
    pub fn swap(&self, variant: &str) -> Result<VariantStatus> {
        let m = &self.shared.metrics;
        let outcome = (|| -> Result<VariantStatus> {
            let manifest = Manifest::load(&self.artifacts)?;
            let loaded = load_release(&manifest, variant)?;
            let mut reg = lock_or_recover(&self.shared.registry);
            let generation = reg.install(variant, loaded);
            let status = reg
                .snapshot()
                .into_iter()
                .find(|s| s.variant == variant)
                .ok_or_else(|| anyhow!("`{variant}` vanished from the registry mid-install"))?;
            debug_assert_eq!(status.generation, generation);
            Ok(status)
        })();
        match &outcome {
            Ok(_) => m.counter_with(names::SWAP_APPLIED, &[("variant", variant)]).inc(),
            Err(_) => m.counter_with(names::SWAP_FAILED, &[("variant", variant)]).inc(),
        }
        outcome
    }

    /// Point-in-time view of the live variant table (generations,
    /// provenance, drain state) — the `{"op":"list"}` payload.
    pub fn registry_snapshot(&self) -> Vec<VariantStatus> {
        lock_or_recover(&self.shared.registry).snapshot()
    }

    /// Queue a session.  Fails fast (no thread hop) on unknown variants
    /// and queue overflow — the same backpressure contract as
    /// `Engine::submit`.
    pub fn open(&self, req: SessionRequest) -> Result<(), SubmitError> {
        if !lock_or_recover(&self.shared.registry).has(&req.variant) {
            return Err(SubmitError::UnknownVariant(req.variant));
        }
        let depth = self.shared.metrics.gauge(names::QUEUE_DEPTH);
        if depth.get() >= self.cfg.queue_depth as i64 {
            return Err(SubmitError::QueueFull {
                variant: req.variant,
                depth: self.cfg.queue_depth,
            });
        }
        depth.add(1);
        self.tx
            .send(Cmd::Open(Pending { req, enqueued: Instant::now() }))
            .map_err(|_| {
                depth.sub(1); // never enqueued: keep the gauge honest
                SubmitError::Stopped
            })
    }

    /// Open a session and block until it finishes; returns the generated
    /// tokens (the non-streaming reply path, and the test harness).
    pub fn generate(&self, variant: &str, prompt: &[i32], max_tokens: usize,
                    temperature: f32, seed: u64) -> Result<Vec<i32>> {
        let (etx, erx) = mpsc::channel();
        self.open(SessionRequest {
            variant: variant.to_string(),
            prompt: prompt.to_vec(),
            image: None,
            max_tokens,
            temperature,
            seed,
            stop_token: None,
            spec: None,
            events: etx,
        })
        .map_err(|e| anyhow!("{e}"))?;
        let mut out = Vec::new();
        for ev in erx {
            match ev {
                GenEvent::Token { token, .. } => out.push(token),
                GenEvent::Done { .. } => return Ok(out),
                GenEvent::Error(e) => bail!("session failed: {e}"),
            }
        }
        bail!("scheduler dropped the session")
    }

    /// [`Self::generate`] with a speculative draft pair — greedy by
    /// contract, so the tokens are bit-identical to plain `generate` at
    /// temperature 0 (the parity the integration tests assert).
    pub fn generate_spec(&self, variant: &str, prompt: &[i32], max_tokens: usize,
                         spec: SpecParams) -> Result<Vec<i32>> {
        let (etx, erx) = mpsc::channel();
        self.open(SessionRequest {
            variant: variant.to_string(),
            prompt: prompt.to_vec(),
            image: None,
            max_tokens,
            temperature: 0.0,
            seed: 1,
            stop_token: None,
            spec: Some(spec),
            events: etx,
        })
        .map_err(|e| anyhow!("{e}"))?;
        let mut out = Vec::new();
        for ev in erx {
            match ev {
                GenEvent::Token { token, .. } => out.push(token),
                GenEvent::Done { .. } => return Ok(out),
                GenEvent::Error(e) => bail!("session failed: {e}"),
            }
        }
        bail!("scheduler dropped the session")
    }

    pub fn stats(&self) -> ServeStats {
        // counters are labeled families (per variant / finish reason):
        // the aggregate view sums every label set
        let m = &self.shared.metrics;
        ServeStats {
            active_sessions: m.gauge(names::ACTIVE_SESSIONS).get(),
            queue_depth: m.gauge(names::QUEUE_DEPTH).get(),
            sessions_opened: m.family_total(names::SESSIONS_OPENED),
            sessions_finished: m.family_total(names::SESSIONS_FINISHED),
            tokens_emitted: m.family_total(names::TOKENS_EMITTED),
            swaps: m.family_total(names::SWAP_APPLIED),
            draining_sessions: m.gauge(names::SWAP_DRAINING_SESSIONS).get(),
        }
    }

    pub fn metrics_text(&self) -> String {
        self.shared.metrics.render()
    }

    /// Prometheus-style exposition (`{"op":"metrics","format":"prom"}`).
    pub fn metrics_prom(&self) -> String {
        self.shared.metrics.render_prom()
    }

    /// The request-lifecycle trace ring (the server's accept/parse spans
    /// record here too).
    pub fn trace(&self) -> &Arc<TraceBuffer> {
        &self.shared.trace
    }

    /// Drain the trace ring as Chrome trace-event JSON (Perfetto-loadable)
    /// — the `{"op":"trace"}` payload.  `clear` empties drained slots.
    pub fn trace_json(&self, clear: bool) -> Json {
        export_chrome(&self.shared.trace.drain(clear))
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Cmd::Stop);
        if let Some(j) = lock_or_recover(&self.join).take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServeRuntime {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Scheduler thread
// ---------------------------------------------------------------------------

/// One admitted session mid-decode.  Holding the `release` Arc is what
/// pins a superseded generation through a hot swap: the registry cannot
/// sweep a release while any `Running` still references it.
struct Running {
    session: DecodeSession,
    /// The release (model + generation) this session decodes against for
    /// its whole lifetime — swaps never re-point a live session.
    release: Arc<ModelRelease>,
    /// Last sampled token — the next `step()` input.
    last: i32,
    temperature: f32,
    rng: XorShift,
    max_tokens: usize,
    /// `max_tokens` was clipped by KV capacity: report `Length`, not
    /// `MaxTokens`, when the clipped budget runs out.
    clipped: bool,
    stop_token: Option<i32>,
    events: mpsc::Sender<GenEvent>,
    emitted: usize,
    /// Per-request wall-clock breakdown (queue/prefill/decode/spec
    /// phases), accumulated as the session advances and delivered on
    /// `Done` — the reply's `"timing"` object.
    timing: RequestTiming,
    /// When the request entered the queue (the `"request"` trace span's
    /// start, and the queue_us baseline).
    enqueued: Instant,
    /// This session's `serve_tokens_emitted{variant=..}` child, resolved
    /// once at admission so the per-token path never locks the registry
    /// map.
    tokens_c: Arc<Counter>,
    done: Option<FinishReason>,
    /// Client hung up or the step failed: evict without a Done event.
    dead: bool,
    /// Speculative pair: draft-side state + the draft release Arc.
    spec: Option<SpecPair>,
}

/// A speculative session's draft half, paired with one `Running` target.
struct SpecPair {
    decoder: SpecDecoder,
    /// The draft release this pair decodes against for its whole
    /// lifetime.  Holding the Arc drains the pair through a hot swap of
    /// the DRAFT variant exactly as `Running::release` does for the
    /// target variant: either swap leaves the pair decoding its pinned
    /// generations until it finishes, then the sweep GCs both.
    release: Arc<ModelRelease>,
}

fn scheduler_main(cfg: ServeConfig, rx: mpsc::Receiver<Cmd>, shared: Arc<ServeShared>) {
    let m = &shared.metrics;
    let trace = shared.trace.clone();
    let queue_g = m.gauge(names::QUEUE_DEPTH);
    let active_g = m.gauge(names::ACTIVE_SESSIONS);
    let kv_bytes_g = m.gauge(names::KV_BYTES);
    let draining_g = m.gauge(names::SWAP_DRAINING_SESSIONS);
    let gced_c = m.counter(names::SWAP_RELEASES_GCED);
    let fused_h = m.histogram(names::FUSED_BATCH_SIZE);
    // serve_sessions_opened / serve_sessions_finished /
    // serve_tokens_emitted / serve_prefill_seconds / serve_step_seconds /
    // serve_spec_proposed / serve_spec_accepted are LABELED families
    // (variant, finish reason) resolved where the label values are known
    // — per admission, per tick group, per eviction; the hot per-token
    // path uses the child Arc cached on `Running`.
    let spec_rate_h = m.histogram(names::SPEC_ACCEPT_RATE);
    // per-tick phase gauges: wall µs the last tick spent drafting vs
    // verifying across its speculative sessions — the heterogeneous
    // step-cost signal (0/0 on ticks with no speculative session)
    let spec_draft_us_g = m.gauge(names::SPEC_DRAFT_US);
    let spec_verify_us_g = m.gauge(names::SPEC_VERIFY_US);
    // GEMM worker count for the forwards this thread runs (thread-local:
    // the knob threads the scheduler's decode, not every caller's matmul).
    set_decode_threads(cfg.decode_threads);

    // deadline 0: a queued session is ready for admission immediately;
    // the batcher contributes per-variant FIFO fairness and grouping.
    let mut waiting: DynamicBatcher<Pending> =
        DynamicBatcher::new(cfg.max_sessions.max(1), Duration::from_millis(0));
    let mut active: Vec<Running> = Vec::new();
    let mut next_id = 1u64;
    let mut stop = false;

    'sched: loop {
        // Ingest: block when idle, otherwise just drain what arrived
        // during the last tick (this is where continuous batching happens:
        // opens land between ticks of everyone else's decode).
        if active.is_empty() && waiting.pending() == 0 {
            if stop {
                break 'sched;
            }
            match rx.recv_timeout(Duration::from_millis(100)) {
                Ok(Cmd::Open(p)) => waiting.push(p),
                Ok(Cmd::Stop) => stop = true,
                Err(mpsc::RecvTimeoutError::Timeout) => continue 'sched,
                Err(mpsc::RecvTimeoutError::Disconnected) => break 'sched,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(Cmd::Open(p)) => waiting.push(p),
                Ok(Cmd::Stop) | Err(mpsc::TryRecvError::Disconnected) => {
                    stop = true;
                    break;
                }
                Err(mpsc::TryRecvError::Empty) => break,
            }
        }
        if stop {
            break 'sched;
        }

        // Admit into free slots (FIFO-fair across variants via the
        // batcher's oldest-head-first poll).
        while active.len() < cfg.max_sessions {
            let free = cfg.max_sessions - active.len();
            let Some(batch) = waiting.poll_up_to(Instant::now(), free) else { break };
            for p in batch.requests {
                queue_g.sub(1);
                m.counter_with(names::SESSIONS_OPENED, &[("variant", &p.req.variant)])
                    .inc();
                // Resolve the variant's CURRENT release at admission time
                // — this is the hot-swap routing point: sessions opened
                // after an install decode the new generation while earlier
                // ones drain on the Arc they already hold.  Speculative
                // sessions resolve their draft under the same lock (same
                // routing semantics, plus the shape-compatibility check).
                let (release, draft) = {
                    let reg = lock_or_recover(&shared.registry);
                    let release = reg.current(&p.req.variant);
                    let draft = match (&release, &p.req.spec) {
                        (Some(rel), Some(sp)) => Some(reg.resolve_draft(&sp.draft, rel)),
                        _ => None,
                    };
                    (release, draft)
                };
                // sessions terminated at admission (zero budget / error)
                // close their books inside admit (reason-labeled)
                if let Some(r) = admit(p, release, draft, &cfg, next_id, m, &trace) {
                    next_id += 1;
                    active.push(r);
                }
            }
        }
        active_g.set(active.len() as i64);
        kv_bytes_g.set(active.iter().map(|r| {
            let draft = r.spec.as_ref().map_or(0, |p| p.decoder.draft_kv_bytes());
            (r.session.kv_bytes() + draft) as i64
        }).sum());

        // Tick: one decode step per live session.  Sessions are grouped
        // by (variant, generation) — mid-drain, old- and new-generation
        // sessions of the same variant hold DIFFERENT weights and must
        // not share a trunk walk — and each multi-session group advances
        // through ONE fused batched trunk walk
        // (`DecodeSession::step_many`), so every weight tile dequantizes
        // once per tick instead of once per session; singleton groups
        // take the plain serial step.
        let mut groups: Vec<(String, u64)> = active
            .iter()
            .filter(|r| r.done.is_none() && !r.dead)
            .map(|r| (r.session.variant.clone(), r.release.generation))
            .collect();
        groups.sort();
        groups.dedup();
        let mut tick_draft_s = 0f64;
        let mut tick_verify_s = 0f64;
        for (var, generation) in groups {
            let group: Vec<&mut Running> = active
                .iter_mut()
                .filter(|r| {
                    r.done.is_none()
                        && !r.dead
                        && r.session.variant == var
                        && r.release.generation == generation
                })
                .collect();
            // clone the Arc BEFORE borrowing the sessions mutably: the
            // model lives behind the same Running structs the fused step
            // needs `&mut` access to
            let release = group[0].release.clone();
            let model = &release.model;
            // Speculative sessions group by (target variant, generation)
            // like everything else but run whole draft/verify rounds with
            // heterogeneous per-session step costs — split them out so
            // the plain sessions still fuse into one trunk walk.
            let (mut specs, mut plain): (Vec<&mut Running>, Vec<&mut Running>) =
                group.into_iter().partition(|r| r.spec.is_some());
            let step_h = m.histogram_with(names::STEP_SECONDS, &[("variant", &var)]);
            let mut fused_done = false;
            if plain.len() >= 2 {
                let tokens: Vec<i32> = plain.iter().map(|r| r.last).collect();
                let t0 = Instant::now();
                let fused = {
                    let mut sessions: Vec<&mut DecodeSession> =
                        plain.iter_mut().map(|r| &mut r.session).collect();
                    DecodeSession::step_many(model, &mut sessions, &tokens)
                };
                if let Ok(all) = fused {
                    // recorded only when the fused walk actually ran —
                    // singleton groups and validation fallbacks step
                    // serially and must not inflate this histogram
                    fused_h.observe_value(plain.len() as f64);
                    // every session waited the whole fused walk for its
                    // token, so each is charged the full wall time — the
                    // fused win shows up as fewer/faster ticks, not as a
                    // fabricated per-session divide
                    let dt = t0.elapsed();
                    trace.push_span(phases::FUSED_STEP, 0, t0, t0 + dt, || {
                        format!("{var} gen={generation} batch={}", plain.len())
                    });
                    for (r, logits) in plain.iter_mut().zip(&all) {
                        r.timing.decode_us += dt.as_micros() as u64;
                        step_h.observe(dt);
                        emit_next(r, logits);
                    }
                    fused_done = true;
                }
                // step_many validates before touching any cache: fall
                // through to serial steps so the failure lands on the
                // offending session, not the whole group.
            }
            if !fused_done {
                for r in plain {
                    step_serial(r, model, &step_h, &trace);
                }
            }
            for r in specs {
                let (d_s, v_s) = step_spec(r, model, &step_h, &spec_rate_h, m, &trace);
                tick_draft_s += d_s;
                tick_verify_s += v_s;
            }
        }
        spec_draft_us_g.set((tick_draft_s * 1e6) as i64);
        spec_verify_us_g.set((tick_verify_s * 1e6) as i64);

        // Evict finished/dead sessions, emitting the terminal event and
        // closing each request's trace span (enqueue → finish).
        let t_evict = Instant::now();
        let mut evicted = 0usize;
        active.retain_mut(|r| {
            if r.dead {
                m.counter_with(names::SESSIONS_FINISHED,
                               &[("variant", &r.session.variant), ("reason", "error")])
                    .inc();
                trace.push_span(phases::REQUEST, r.session.id, r.enqueued, Instant::now(), || {
                    format!("{} reason=error tokens={}", r.session.variant, r.emitted)
                });
                evicted += 1;
                return false;
            }
            if let Some(reason) = r.done {
                // count before notifying: a client that wakes on Done must
                // already see itself in `sessions_finished`
                m.counter_with(
                    names::SESSIONS_FINISHED,
                    &[("variant", &r.session.variant), ("reason", reason.as_str())],
                )
                .inc();
                r.timing.tokens = r.emitted as u64;
                // record the lifecycle span BEFORE notifying: a client that
                // wakes on Done and drains the ring must find its request
                trace.push_span(phases::REQUEST, r.session.id, r.enqueued, Instant::now(), || {
                    format!("{} reason={} tokens={}", r.session.variant, reason.as_str(),
                            r.emitted)
                });
                let _ = r.events.send(GenEvent::Done {
                    n_tokens: r.emitted,
                    reason,
                    timing: r.timing,
                });
                evicted += 1;
                return false;
            }
            true
        });
        // Re-set the gauges AFTER evictions (not only at admission): a
        // long tick must not report already-evicted ghost sessions or
        // their freed KV bytes until the next tick starts.
        active_g.set(active.len() as i64);
        kv_bytes_g.set(active.iter().map(|r| {
            let draft = r.spec.as_ref().map_or(0, |p| p.decoder.draft_kv_bytes());
            (r.session.kv_bytes() + draft) as i64
        }).sum());

        // GC point: evictions above dropped Running (and its release Arc)
        // for finished sessions, so superseded releases whose last session
        // just ended are reclaimable right now.
        {
            let mut reg = lock_or_recover(&shared.registry);
            let freed = reg.sweep();
            if freed > 0 {
                gced_c.add(freed as u64);
            }
            draining_g.set(reg.draining_sessions() as i64);
        }
        if evicted > 0 {
            // sweep span covers the evictions plus the registry GC pass
            trace.push_span(phases::EVICT_SWEEP, 0, t_evict, Instant::now(),
                            || format!("evicted={evicted}"));
        }
    }

    // Shutdown: everything still queued or mid-decode gets an Error event
    // (clients observe a clean terminal line instead of a hangup).
    loop {
        match rx.try_recv() {
            Ok(Cmd::Open(p)) => waiting.push(p),
            Ok(Cmd::Stop) => {}
            Err(_) => break,
        }
    }
    for batch in waiting.drain_all() {
        for p in batch.requests {
            queue_g.sub(1);
            let _ = p.req.events.send(GenEvent::Error("scheduler stopped".into()));
        }
    }
    for r in active.drain(..) {
        // these were opened (counted): close the books before notifying
        m.counter_with(names::SESSIONS_FINISHED,
                       &[("variant", &r.session.variant), ("reason", "error")])
            .inc();
        let _ = r.events.send(GenEvent::Error("scheduler stopped".into()));
    }
    active_g.set(0);
    kv_bytes_g.set(0);
}

/// One serial decode step with timing, emission, and error handling —
/// the singleton-group tick and the fused path's validation fallback.
fn step_serial(r: &mut Running, model: &FactorizedModel,
               step_h: &crate::metrics::Histogram, trace: &TraceBuffer) {
    let t0 = Instant::now();
    match r.session.step(model, r.last) {
        Ok(logits) => {
            let dt = t0.elapsed();
            r.timing.decode_us += dt.as_micros() as u64;
            step_h.observe(dt);
            trace.push_span(phases::STEP, r.session.id, t0, t0 + dt,
                            || r.session.variant.clone());
            emit_next(r, &logits);
        }
        Err(e) => {
            let _ = r.events.send(GenEvent::Error(format!("{e:#}")));
            r.dead = true;
        }
    }
}

/// One speculative draft/verify round with timing, metrics, and error
/// handling — the spec-session counterpart of [`step_serial`].  The
/// round's target logits rows flow through the same [`emit_next`] gate
/// as plain steps (greedy argmax of each row == the round's accepted
/// candidates then the correction token), so stop-token / budget /
/// capacity termination and streaming are shared code.  Returns the
/// round's (draft, verify) phase wall times for the per-tick gauges.
fn step_spec(r: &mut Running, target_model: &FactorizedModel,
             step_h: &crate::metrics::Histogram, rate_h: &crate::metrics::Histogram,
             m: &Registry, trace: &TraceBuffer) -> (f64, f64) {
    let t0 = Instant::now();
    let outcome = match r.spec.as_mut() {
        Some(pair) => {
            pair.decoder.round(&pair.release.model, target_model, &mut r.session, r.last)
        }
        // the caller partitions on spec.is_some(); reaching here is a
        // scheduler bug, surfaced as a session error instead of a panic
        None => Err(anyhow!("step_spec called on a plain session")),
    };
    match outcome {
        Ok(round) => {
            let dt = t0.elapsed();
            let t1 = t0 + dt;
            r.timing.decode_us += dt.as_micros() as u64;
            r.timing.draft_us += (round.draft_s * 1e6) as u64;
            r.timing.verify_us += (round.verify_s * 1e6) as u64;
            step_h.observe(dt);
            let variant = r.session.variant.as_str();
            m.counter_with(names::SPEC_PROPOSED, &[("variant", variant)])
                .add(round.proposed as u64);
            m.counter_with(names::SPEC_ACCEPTED, &[("variant", variant)])
                .add(round.accepted as u64);
            if round.proposed > 0 {
                rate_h.observe_value(round.accepted as f64 / round.proposed as f64);
            }
            // the round ran draft-then-verify back to back: reconstruct
            // both phase spans from the measured phase wall times
            let d_end = t0 + Duration::from_secs_f64(round.draft_s);
            trace.push_span(phases::SPEC_DRAFT, r.session.id, t0, d_end,
                            || format!("{variant} proposed={}", round.proposed));
            let v_start = t1
                .checked_sub(Duration::from_secs_f64(round.verify_s))
                .unwrap_or(t0);
            trace.push_span(phases::SPEC_VERIFY, r.session.id, v_start, t1,
                            || format!("{variant} accepted={}", round.accepted));
            for row in &round.rows {
                emit_next(r, row);
                if r.done.is_some() || r.dead {
                    break;
                }
            }
            (round.draft_s, round.verify_s)
        }
        Err(e) => {
            let _ = r.events.send(GenEvent::Error(format!("{e:#}")));
            r.dead = true;
            (0.0, 0.0)
        }
    }
}

/// Prefill a newly admitted session and emit its first token.  Returns
/// None when the session terminated at admission (zero budget, prefill
/// error, or client already gone) — those paths close the session's
/// books (`serve_sessions_finished{variant,reason}`) here.  `release` is
/// the registry's current release for the variant, resolved by the
/// caller at admission time; `draft` is the resolved speculative draft
/// release (present iff the request asked for speculative decode and the
/// target release exists — resolution/compatibility errors surface to
/// the client here).
fn admit(p: Pending, release: Option<Arc<ModelRelease>>,
         draft: Option<Result<Arc<ModelRelease>>>, cfg: &ServeConfig,
         id: u64, m: &Registry, trace: &TraceBuffer) -> Option<Running> {
    let t_adm = Instant::now();
    let req = p.req;
    let queue_us = t_adm.saturating_duration_since(p.enqueued).as_micros() as u64;
    trace.push_span(phases::QUEUE_WAIT, id, p.enqueued, t_adm, || req.variant.clone());
    let finished = |reason: &str| {
        m.counter_with(names::SESSIONS_FINISHED,
                       &[("variant", &req.variant), ("reason", reason)])
            .inc();
    };
    let Some(release) = release else {
        // open() validates; a missing release here means start/open disagree
        let _ = req.events.send(GenEvent::Error(format!("unknown variant `{}`", req.variant)));
        finished("error");
        return None;
    };
    let model = &release.model;
    // Speculative setup fails fast, before any prefill work: a refused
    // draft (unknown / shape-incompatible) or a non-greedy request is a
    // terminal error, never a silent fallback to plain decode.
    let spec_setup = match (&req.spec, draft) {
        (None, _) => None,
        (Some(sp), Some(Ok(d))) => Some((sp.k.max(1), d)),
        (Some(_), Some(Err(e))) => {
            let _ = req.events.send(GenEvent::Error(format!("{e:#}")));
            finished("error");
            return None;
        }
        (Some(sp), None) => {
            let _ = req.events.send(GenEvent::Error(format!(
                "draft variant `{}` was not resolved", sp.draft)));
            finished("error");
            return None;
        }
    };
    if spec_setup.is_some() && req.temperature > 0.0 {
        let _ = req.events.send(GenEvent::Error(
            "speculative decode is greedy-only: temperature must be 0".into()));
        finished("error");
        return None;
    }
    if req.max_tokens == 0 {
        let _ = req.events.send(GenEvent::Done {
            n_tokens: 0,
            reason: FinishReason::MaxTokens,
            timing: RequestTiming { queue_us, ..Default::default() },
        });
        finished(FinishReason::MaxTokens.as_str());
        return None;
    }
    // Budget the KV capacity: the prompt comes first (context quality —
    // oversize prompts keep their most recent tail, the sliding-window
    // semantics of the old serve path, leaving one slot to step into),
    // then the generation budget is clipped to what the cache can still
    // hold: g tokens cost g−1 steps after the prefill row.
    let prefix = if req.image.is_some() { model.n_img_tokens } else { 0 };
    let cap = cfg.kv_capacity;
    if prefix + 2 > cap {
        let _ = req.events.send(GenEvent::Error(format!(
            "kv capacity {cap} cannot hold the {prefix}-token image prefix"
        )));
        finished("error");
        return None;
    }
    let mut prompt = req.prompt;
    if prompt.is_empty() {
        prompt.push(b' ' as i32);
    }
    let keep = prompt.len().min(cap - prefix - 1);
    if keep < prompt.len() {
        prompt.drain(..prompt.len() - keep);
    }
    let gen_budget = req.max_tokens.min(cap - prefix - keep + 1);
    let mut session = DecodeSession::new(id, &req.variant, model, cap);
    let t0 = Instant::now();
    let logits = match session.prefill(model, &prompt, req.image.as_deref()) {
        Ok(l) => l,
        Err(e) => {
            let _ = req.events.send(GenEvent::Error(format!("{e:#}")));
            finished("error");
            return None;
        }
    };
    // The draft half shares the (already clipped) prompt and image: both
    // caches attend the identical context, so draft candidates and target
    // verify rows speak about the same positions.
    let spec = match spec_setup {
        None => None,
        Some((k, drel)) => {
            let mut dsess = DecodeSession::new(id, &drel.variant, &drel.model, cap);
            if let Err(e) = dsess.prefill(&drel.model, &prompt, req.image.as_deref()) {
                let _ = req.events.send(GenEvent::Error(format!("draft prefill: {e:#}")));
                finished("error");
                return None;
            }
            Some(SpecPair { decoder: SpecDecoder::new(dsess, k), release: drel })
        }
    };
    let dt = t0.elapsed();
    m.histogram_with(names::PREFILL_SECONDS, &[("variant", &req.variant)])
        .observe(dt);
    trace.push_span(phases::PREFILL, id, t0, t0 + dt, || {
        format!("{} prompt={} spec={}", req.variant, keep, spec.is_some())
    });
    trace.push_span(phases::ADMISSION, id, t_adm, Instant::now(), || req.variant.clone());
    // resolved once per session so the per-token hot path below never
    // takes the registry map lock, only the child counter's atomic
    let tokens_c = m.counter_with(names::TOKENS_EMITTED, &[("variant", &req.variant)]);
    let mut r = Running {
        session,
        release: release.clone(),
        last: 0,
        temperature: req.temperature,
        rng: XorShift::new(req.seed.max(1)),
        max_tokens: gen_budget,
        clipped: gen_budget < req.max_tokens,
        stop_token: req.stop_token,
        events: req.events,
        emitted: 0,
        timing: RequestTiming {
            queue_us,
            prefill_us: dt.as_micros() as u64,
            ..Default::default()
        },
        enqueued: p.enqueued,
        tokens_c,
        done: None,
        dead: false,
        spec,
    };
    emit_next(&mut r, &logits);
    Some(r)
}

/// Sample from `logits`, stream the token, and update the session's
/// stop conditions.
fn emit_next(r: &mut Running, logits: &[f32]) {
    let tok = sample_logits(logits, r.temperature, &mut r.rng) as i32;
    r.last = tok;
    let index = r.emitted;
    r.emitted += 1;
    r.tokens_c.inc();
    if r.events.send(GenEvent::Token { index, token: tok }).is_err() {
        r.dead = true; // client hung up: free the slot without more work
        return;
    }
    if r.stop_token == Some(tok) {
        r.done = Some(FinishReason::Stop);
    } else if r.emitted >= r.max_tokens {
        r.done = Some(if r.clipped { FinishReason::Length } else { FinishReason::MaxTokens });
    } else if r.session.remaining() == 0 {
        r.done = Some(FinishReason::Length);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowrank::synth::{tiny_manifest_json, tiny_store_tensors, SynthStyle, TinyDims};
    use crate::storage::write_store;

    fn dims() -> TinyDims {
        TinyDims { vocab: 256, d: 24, heads: 2, layers: 2, ff: 32 }
    }

    fn artifacts(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dobi_serve_sched_{tag}"));
        std::fs::create_dir_all(&dir).unwrap();
        write_store(&dir.join("dense.dobiw"),
                    &tiny_store_tensors(dims(), 0, SynthStyle::DenseF32)).unwrap();
        // a factorized q8 twin of the same weights: the speculative draft
        write_store(&dir.join("q8.dobiw"),
                    &tiny_store_tensors(dims(), 0, SynthStyle::FactorQ8)).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            tiny_manifest_json(dims(), 0, &[
                ("tiny/dense", "dense", 1.0, "dense.dobiw"),
                ("tiny/q8", "factorized", 0.6, "q8.dobiw"),
            ]),
        )
        .unwrap();
        dir
    }

    fn rt(tag: &str, cfg: ServeConfig) -> ServeRuntime {
        ServeRuntime::start(artifacts(tag), &["tiny/dense".to_string()], cfg).unwrap()
    }

    #[test]
    fn generate_emits_exactly_max_tokens() {
        let rt = rt("gen", ServeConfig { max_sessions: 2, ..Default::default() });
        let prompt: Vec<i32> = "The ".bytes().map(|b| b as i32).collect();
        let out = rt.generate("tiny/dense", &prompt, 7, 0.0, 1).unwrap();
        assert_eq!(out.len(), 7);
        assert!(out.iter().all(|&t| (0..256).contains(&t)));
        let again = rt.generate("tiny/dense", &prompt, 7, 0.0, 99).unwrap();
        assert_eq!(out, again, "greedy decode is seed-independent");
        let st = rt.stats();
        assert_eq!(st.sessions_finished, 2);
        assert_eq!(st.tokens_emitted, 14);
        assert_eq!(st.active_sessions, 0);
        rt.shutdown();
    }

    #[test]
    fn open_rejects_unknown_variant_and_zero_budget_finishes_clean() {
        let rt = rt("rej", ServeConfig::default());
        let (etx, _erx) = mpsc::channel();
        let bad = rt.open(SessionRequest {
            variant: "tiny/nope".into(),
            prompt: vec![1],
            image: None,
            max_tokens: 4,
            temperature: 0.0,
            seed: 1,
            stop_token: None,
            spec: None,
            events: etx,
        });
        assert!(matches!(bad, Err(SubmitError::UnknownVariant(_))));
        let out = rt.generate("tiny/dense", &[1, 2], 0, 0.0, 1).unwrap();
        assert!(out.is_empty(), "max_tokens=0 must finish with zero tokens");
        rt.shutdown();
    }

    #[test]
    fn stop_token_ends_the_stream_early() {
        let rt = rt("stop", ServeConfig::default());
        // discover what greedy emits first, then ask to stop on it
        let first = rt.generate("tiny/dense", &[65, 66], 1, 0.0, 1).unwrap()[0];
        let (etx, erx) = mpsc::channel();
        rt.open(SessionRequest {
            variant: "tiny/dense".into(),
            prompt: vec![65, 66],
            image: None,
            max_tokens: 32,
            temperature: 0.0,
            seed: 1,
            stop_token: Some(first),
            spec: None,
            events: etx,
        })
        .unwrap();
        let mut got = Vec::new();
        let mut reason = None;
        for ev in erx {
            match ev {
                GenEvent::Token { token, .. } => got.push(token),
                GenEvent::Done { reason: r, .. } => {
                    reason = Some(r);
                    break;
                }
                GenEvent::Error(e) => panic!("unexpected error: {e}"),
            }
        }
        assert_eq!(got, vec![first], "stream stops on (and includes) the stop token");
        assert_eq!(reason, Some(FinishReason::Stop));
        rt.shutdown();
    }

    /// Run one session to completion, returning (tokens emitted, reason).
    fn run_session(rt: &ServeRuntime, prompt: Vec<i32>, max_tokens: usize)
                   -> (usize, FinishReason) {
        let (etx, erx) = mpsc::channel();
        rt.open(SessionRequest {
            variant: "tiny/dense".into(),
            prompt,
            image: None,
            max_tokens,
            temperature: 0.0,
            seed: 1,
            stop_token: None,
            spec: None,
            events: etx,
        })
        .unwrap();
        let mut n = 0usize;
        for ev in erx {
            match ev {
                GenEvent::Token { .. } => n += 1,
                GenEvent::Done { n_tokens, reason, .. } => {
                    assert_eq!(n_tokens, n);
                    return (n, reason);
                }
                GenEvent::Error(e) => panic!("unexpected error: {e}"),
            }
        }
        panic!("stream ended without Done");
    }

    #[test]
    fn fused_metrics_and_threaded_decode_exported() {
        let rt = Arc::new(rt(
            "fused",
            ServeConfig { max_sessions: 4, decode_threads: 2, ..Default::default() },
        ));
        let prompt: Vec<i32> = "The ".bytes().map(|b| b as i32).collect();
        let mut handles = Vec::new();
        for i in 0..3u64 {
            let rt2 = rt.clone();
            let p = prompt.clone();
            handles.push(std::thread::spawn(move || {
                rt2.generate("tiny/dense", &p, 12, 0.0, 1 + i).unwrap()
            }));
        }
        let outs: Vec<Vec<i32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // greedy: identical prompts decode identically no matter how many
        // sessions shared a fused tick (and with the GEMM threaded)
        assert_eq!(outs[0], outs[1]);
        assert_eq!(outs[1], outs[2]);
        let text = rt.metrics_text();
        assert!(text.contains("serve_fused_batch_size"), "{text}");
        assert!(text.contains("serve_kv_bytes"), "{text}");
        let st = rt.stats();
        assert_eq!(st.sessions_finished, 3);
        rt.shutdown();
        // scheduler joined: the gauges must have settled, no ghost bytes
        assert_eq!(rt.shared.metrics.gauge("serve_kv_bytes").get(), 0,
                   "freed sessions must not leave ghost KV bytes on the gauge");
        assert_eq!(rt.shared.metrics.gauge("serve_active_sessions").get(), 0);
    }

    #[test]
    fn swap_bumps_generation_and_keeps_serving() {
        let rt = rt("swap", ServeConfig::default());
        let prompt: Vec<i32> = "The ".bytes().map(|b| b as i32).collect();
        let before = rt.generate("tiny/dense", &prompt, 6, 0.0, 1).unwrap();
        // same bytes on disk: the swap installs an identical generation 2
        let status = rt.swap("tiny/dense").unwrap();
        assert_eq!(status.generation, 2);
        assert_eq!(rt.stats().swaps, 1);
        let after = rt.generate("tiny/dense", &prompt, 6, 0.0, 1).unwrap();
        assert_eq!(before, after, "identical weights decode identically across the swap");
        // swapping a variant the manifest doesn't know fails without
        // touching the table
        assert!(rt.swap("tiny/nope").is_err());
        let snap = rt.registry_snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].generation, 2);
        // nobody held generation 1 past its eviction: the tick sweep frees
        // it; poll briefly since GC happens on the scheduler thread
        let t0 = Instant::now();
        while rt.shared.metrics.counter("serve_swap_releases_gced").get() == 0 {
            assert!(t0.elapsed() < Duration::from_secs(5), "generation 1 never GCed");
            // ticks only happen while sessions run: drive one
            rt.generate("tiny/dense", &prompt, 1, 0.0, 1).unwrap();
            std::thread::sleep(Duration::from_millis(10));
        }
        rt.shutdown();
    }

    #[test]
    fn kv_capacity_clips_generation_with_length_reason() {
        let rt = rt("cap", ServeConfig { kv_capacity: 8, ..Default::default() });
        // prompt longer than capacity: the most recent 7 tokens are kept
        // (prompt has priority), leaving 1 step slot -> 2 tokens emitted
        let (n, reason) = run_session(&rt, (0..20).collect(), 100);
        assert_eq!(n, 2, "7-token prompt tail + 1 step slot = 2 tokens");
        assert_eq!(reason, FinishReason::Length, "clipped budget reports length");
        // short prompt: the rest of the cache goes to generation
        let (n, reason) = run_session(&rt, vec![1, 2], 100);
        assert_eq!(n, 7, "2 prompt rows + 6 step slots = 7 tokens");
        assert_eq!(reason, FinishReason::Length);
        // fits entirely: max_tokens honored with the normal reason
        let (n, reason) = run_session(&rt, vec![1, 2], 3);
        assert_eq!(n, 3);
        assert_eq!(reason, FinishReason::MaxTokens);
        rt.shutdown();
    }

    /// Runtime serving both the dense target and its q8 factorized twin
    /// (the speculative draft).
    fn rt_spec(tag: &str, cfg: ServeConfig) -> ServeRuntime {
        ServeRuntime::start(
            artifacts(tag),
            &["tiny/dense".to_string(), "tiny/q8".to_string()],
            cfg,
        )
        .unwrap()
    }

    #[test]
    fn spec_generate_bit_identical_to_plain_and_metrics_exported() {
        let rt = rt_spec("spec", ServeConfig { max_sessions: 2, ..Default::default() });
        let prompt: Vec<i32> = "The quick".bytes().map(|b| b as i32).collect();
        let want = rt.generate("tiny/dense", &prompt, 16, 0.0, 1).unwrap();
        for k in [1usize, 4] {
            let got = rt
                .generate_spec("tiny/dense", &prompt, 16,
                               SpecParams { draft: "tiny/q8".into(), k })
                .unwrap();
            assert_eq!(got, want, "speculative greedy decode diverged (k {k})");
        }
        // self-drafting (target drafts for itself) is legal and exact too
        let self_spec = rt
            .generate_spec("tiny/dense", &prompt, 16,
                           SpecParams { draft: "tiny/dense".into(), k: 4 })
            .unwrap();
        assert_eq!(self_spec, want);
        let m = &rt.shared.metrics;
        // spec counters are labeled by target variant: read the family sum
        let proposed = m.family_total("serve_spec_proposed");
        let accepted = m.family_total("serve_spec_accepted");
        assert!(proposed > 0, "spec rounds must report proposals");
        assert!(accepted <= proposed);
        let text = rt.metrics_text();
        assert!(text.contains("serve_spec_accept_rate"), "{text}");
        assert!(text.contains("serve_spec_draft_us"), "{text}");
        assert!(text.contains("serve_spec_verify_us"), "{text}");
        assert!(text.contains(r#"serve_spec_proposed{variant="tiny/dense"}"#), "{text}");
        rt.shutdown();
    }

    #[test]
    fn timing_and_trace_cover_the_request_lifecycle() {
        let rt = rt("trace", ServeConfig { max_sessions: 2, ..Default::default() });
        let (etx, erx) = mpsc::channel();
        rt.open(SessionRequest {
            variant: "tiny/dense".into(),
            prompt: vec![65, 66, 67],
            image: None,
            max_tokens: 5,
            temperature: 0.0,
            seed: 1,
            stop_token: None,
            spec: None,
            events: etx,
        })
        .unwrap();
        let mut timing = None;
        for ev in erx {
            if let GenEvent::Done { n_tokens, timing: t, .. } = ev {
                assert_eq!(n_tokens, 5);
                timing = Some(t);
                break;
            }
        }
        let t = timing.expect("Done must carry the timing summary");
        assert_eq!(t.tokens, 5);
        assert!(t.prefill_us > 0, "prefill wall time must be charged");
        assert!(t.decode_us > 0, "decode wall time must be charged");
        assert_eq!(t.ttft_us(), t.queue_us + t.prefill_us);
        // `evict_sweep` is the tick's last push — once it lands the ring is
        // stable for this workload (poll: the sweep runs on the scheduler
        // thread after Done is delivered)
        let t0 = Instant::now();
        let events = loop {
            let events = rt.trace().drain(false);
            if events.iter().any(|e| e.name == "evict_sweep") {
                break events;
            }
            assert!(t0.elapsed() < Duration::from_secs(5), "evict_sweep never recorded");
            std::thread::sleep(Duration::from_millis(5));
        };
        let names: Vec<&str> = events.iter().map(|e| e.name).collect();
        for want in ["queue_wait", "admission", "prefill", "step", "request"] {
            assert!(names.contains(&want), "missing `{want}` span in {names:?}");
        }
        // export round-trips through the JSON layer
        let doc = rt.trace_json(true);
        let evs = doc.path("traceEvents").and_then(|j| j.as_arr().map(|a| a.len()));
        assert_eq!(evs, Some(events.len()));
        assert!(rt.trace().drain(false).is_empty(), "clear=true must empty the ring");
        rt.shutdown();
    }

    #[test]
    fn disabled_trace_buffer_serves_without_recording() {
        let rt = rt("notrace", ServeConfig { trace_buffer: 0, ..Default::default() });
        let out = rt.generate("tiny/dense", &[1, 2, 3], 4, 0.0, 1).unwrap();
        assert_eq!(out.len(), 4);
        assert!(!rt.trace().enabled());
        assert!(rt.trace().drain(false).is_empty());
        rt.shutdown();
    }

    /// Open a session expected to die at admission; returns the Error text.
    fn expect_admission_error(rt: &ServeRuntime, temperature: f32,
                              spec: Option<SpecParams>) -> String {
        let (etx, erx) = mpsc::channel();
        rt.open(SessionRequest {
            variant: "tiny/dense".into(),
            prompt: vec![1, 2, 3],
            image: None,
            max_tokens: 8,
            temperature,
            seed: 1,
            stop_token: None,
            spec,
            events: etx,
        })
        .unwrap();
        for ev in erx {
            match ev {
                GenEvent::Error(e) => return e,
                other => panic!("expected an admission error, got {other:?}"),
            }
        }
        panic!("stream ended without an Error event");
    }

    #[test]
    fn spec_refuses_non_greedy_and_bad_drafts() {
        let rt = rt_spec("spec_rej", ServeConfig::default());
        let sp = SpecParams { draft: "tiny/q8".into(), k: 4 };
        let e = expect_admission_error(&rt, 0.7, Some(sp));
        assert!(e.contains("greedy-only"), "{e}");
        let e = expect_admission_error(
            &rt, 0.0, Some(SpecParams { draft: "tiny/nope".into(), k: 4 }));
        assert!(e.contains("unknown draft variant"), "{e}");
        // refused sessions still close the books
        let st = rt.stats();
        assert_eq!(st.sessions_opened, st.sessions_finished);
        assert_eq!(st.active_sessions, 0);
        rt.shutdown();
    }
}
