//! The typed request protocol and token streaming over the TCP line
//! framing.
//!
//! Every inbound line parses through [`parse_request`] into a [`Request`]
//! — generate (the default when `op` is absent), or the control ops
//! `swap` / `list` / `health` / `metrics` / `trace` (the last two are the
//! observability surface: the labeled metric families as text or
//! Prometheus exposition, and the request-lifecycle span ring as Chrome
//! trace-event JSON).  A generate request (one JSON object per line, same
//! as the one-shot path, plus the `stream` switch):
//!   -> {"variant": "tiny/dobi_40", "prompt": "The ", "max_tokens": 32,
//!       "temperature": 0.0, "stream": true, "stop_token": 10}
//!
//! Streaming reply: one line per generated token, then a terminal line —
//!   <- {"id": 1, "index": 0, "delta": "t", "token": 116, "done": false}
//!   <- ...
//!   <- {"id": 1, "done": true, "text": "the...", "n_tokens": 32,
//!       "finish": "max_tokens", "latency_s": 0.01, "tokens_per_s": 3200.0}
//!
//! Without `"stream": true` the reply is the single legacy object
//! (`{"id", "text", "latency_s", "tokens_per_s"}`), but still decoded
//! incrementally through the scheduler when it serves the variant.
//! Both reply shapes attach the scheduler's per-request wall-clock
//! breakdown as a `"timing"` object (`queue_us`, `prefill_us`,
//! `decode_us`, `draft_us`, `verify_us`, `ttft_us`, `tokens`,
//! `tokens_per_s` — see [`crate::trace::RequestTiming`]).
//!
//! Deltas are per-token byte decodes: a multi-byte UTF-8 character split
//! across tokens renders as replacement characters in the deltas; the
//! terminal line's `text` is the lossless whole-stream decode clients
//! should reconcile against.

use std::collections::BTreeMap;
use std::io::Write;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::json::Json;
use crate::tokenizer::ByteTokenizer;
use crate::trace::RequestTiming;

use super::scheduler::{FinishReason, GenEvent, ServeRuntime, SessionRequest};
use super::spec::SpecParams;

/// Generation parameters shared by the streaming and one-shot paths.
#[derive(Debug, Clone)]
pub struct GenParams {
    pub variant: String,
    pub prompt: String,
    /// Raw image features for VLM variants, prepended as the session's
    /// image prefix at prefill (`"image": [..]` on the wire).
    pub image: Option<Vec<f32>>,
    pub max_tokens: usize,
    pub temperature: f32,
    pub seed: u64,
    pub stop_token: Option<i32>,
    pub stream: bool,
    /// Speculative decode: `"spec": {"draft": "<variant>", "k": N}` on the
    /// wire (the server may also fill this from its `--spec-draft`/
    /// `--spec-k` defaults).  Greedy-only; output stays bit-identical.
    pub spec: Option<SpecParams>,
}

/// One request line, typed.  Every op the wire protocol speaks is parsed
/// in exactly one place ([`parse_request`]); the server dispatches on the
/// variant and never touches raw JSON fields again.
#[derive(Debug, Clone)]
pub enum Request {
    /// Run a generation (the default when `op` is absent — every
    /// pre-registry client line still means this).
    Generate(GenParams),
    /// Hot-swap `variant` to whatever its manifest entry points at now.
    Swap { variant: String },
    /// Snapshot the live variant table (generations, provenance, drain).
    List,
    /// Liveness + aggregate serve counters.
    Health,
    /// Dump the metric families — `prom` selects the Prometheus-style
    /// exposition (`"format": "prom"`) over the plain-text render.
    Metrics { prom: bool },
    /// Drain the request-lifecycle span ring as Chrome trace-event JSON;
    /// `"clear": true` empties the drained slots.
    Trace { clear: bool },
}

/// Protocol v1 vocabulary: every op [`parse_request`] dispatches on.
/// The README's protocol table documents exactly this set — `dobi lint`'s
/// `protocol-drift` rule holds the two (and the parse code) in sync.
pub const PROTOCOL_OPS: &[&str] = &["generate", "swap", "list", "health", "metrics", "trace"];

/// Protocol v1 vocabulary: every request field [`parse_request`] reads
/// (including the `spec` object's nested `draft`/`k`). Same drift contract
/// as [`PROTOCOL_OPS`].
pub const PROTOCOL_FIELDS: &[&str] = &[
    "op",
    "variant",
    "prompt",
    "image",
    "max_tokens",
    "temperature",
    "seed",
    "stop_token",
    "stream",
    "spec",
    "draft",
    "k",
    "format",
    "clear",
];

/// A malformed request line: which field was wrong (when attributable)
/// and why.  Serialized as `{"id", "error", "field"}` by the server.
#[derive(Debug, Clone)]
pub struct ReqError {
    pub field: Option<String>,
    pub msg: String,
}

impl ReqError {
    fn field(name: &str, msg: String) -> ReqError {
        ReqError { field: Some(name.to_string()), msg }
    }
}

fn json_type(v: &Json) -> &'static str {
    match v {
        Json::Null => "null",
        Json::Bool(_) => "a bool",
        Json::Num(_) => "a number",
        Json::Str(_) => "a string",
        Json::Arr(_) => "an array",
        Json::Obj(_) => "an object",
    }
}

/// Typed field access: absent (or explicit `null`) falls back to the
/// default, but a PRESENT field of the wrong type is an error naming the
/// field — silent coercion is how a client's `"max_tokens": "32"` turns
/// into a confusing default instead of a fixable diagnostic.
fn opt_str(req: &Json, name: &str, default: &str) -> Result<String, ReqError> {
    match req.get(name) {
        None | Some(Json::Null) => Ok(default.to_string()),
        Some(Json::Str(s)) => Ok(s.clone()),
        Some(v) => Err(ReqError::field(
            name,
            format!("`{name}` must be a string, got {}", json_type(v)),
        )),
    }
}

fn opt_num(req: &Json, name: &str, default: f64) -> Result<f64, ReqError> {
    match req.get(name) {
        None | Some(Json::Null) => Ok(default),
        Some(Json::Num(n)) => Ok(*n),
        Some(v) => Err(ReqError::field(
            name,
            format!("`{name}` must be a number, got {}", json_type(v)),
        )),
    }
}

fn opt_uint(req: &Json, name: &str, default: Option<u64>) -> Result<Option<u64>, ReqError> {
    match req.get(name) {
        None | Some(Json::Null) => Ok(default),
        Some(Json::Num(n)) if n.fract() == 0.0 && *n >= 0.0 => Ok(Some(*n as u64)),
        Some(Json::Num(n)) => Err(ReqError::field(
            name,
            format!("`{name}` must be a non-negative integer, got {n}"),
        )),
        Some(v) => Err(ReqError::field(
            name,
            format!("`{name}` must be a non-negative integer, got {}", json_type(v)),
        )),
    }
}

fn opt_bool(req: &Json, name: &str, default: bool) -> Result<bool, ReqError> {
    match req.get(name) {
        None | Some(Json::Null) => Ok(default),
        Some(Json::Bool(b)) => Ok(*b),
        Some(v) => Err(ReqError::field(
            name,
            format!("`{name}` must be a bool, got {}", json_type(v)),
        )),
    }
}

/// Optional `"image": [f32, ...]` — VLM image features, every element a
/// number (the first offending index is named in the error).
fn opt_image(req: &Json) -> Result<Option<Vec<f32>>, ReqError> {
    match req.get("image") {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Arr(xs)) => {
            let mut out = Vec::with_capacity(xs.len());
            for (i, x) in xs.iter().enumerate() {
                match x {
                    Json::Num(n) => out.push(*n as f32),
                    v => {
                        return Err(ReqError::field(
                            "image",
                            format!("`image[{i}]` must be a number, got {}", json_type(v)),
                        ))
                    }
                }
            }
            Ok(Some(out))
        }
        Some(v) => Err(ReqError::field(
            "image",
            format!("`image` must be an array of numbers, got {}", json_type(v)),
        )),
    }
}

/// Optional `"spec": {"draft": "<variant>", "k": N}` — `draft` is a
/// required non-empty string, `k` an optional positive integer
/// (default 4, matching the serve CLI default).
fn opt_spec(req: &Json) -> Result<Option<SpecParams>, ReqError> {
    let o = match req.get("spec") {
        None | Some(Json::Null) => return Ok(None),
        Some(o @ Json::Obj(_)) => o,
        Some(v) => {
            return Err(ReqError::field(
                "spec",
                format!("`spec` must be an object, got {}", json_type(v)),
            ))
        }
    };
    let draft = match o.get("draft") {
        Some(Json::Str(s)) if !s.is_empty() => s.clone(),
        Some(Json::Str(_)) | Some(Json::Null) | None => {
            return Err(ReqError::field(
                "spec.draft",
                "`spec.draft` must name the draft variant".into(),
            ))
        }
        Some(v) => {
            return Err(ReqError::field(
                "spec.draft",
                format!("`spec.draft` must be a string, got {}", json_type(v)),
            ))
        }
    };
    let k = match o.get("k") {
        None | Some(Json::Null) => 4,
        Some(Json::Num(n)) if n.fract() == 0.0 && *n >= 1.0 => *n as usize,
        Some(Json::Num(n)) => {
            return Err(ReqError::field(
                "spec.k",
                format!("`spec.k` must be a positive integer, got {n}"),
            ))
        }
        Some(v) => {
            return Err(ReqError::field(
                "spec.k",
                format!("`spec.k` must be a positive integer, got {}", json_type(v)),
            ))
        }
    };
    Ok(Some(SpecParams { draft, k }))
}

/// Parse one request line into a typed [`Request`].
///
/// Back-compat contract: a line with no `op` is a generate — every field
/// keeps its historical default (`variant`/`prompt` empty, `max_tokens`
/// 32, greedy, no stop token, one-shot reply) so pre-registry clients
/// work unchanged.  What tightened: a field that IS present with the
/// wrong type no longer coerces silently — it errors, naming the field.
pub fn parse_request(req: &Json) -> Result<Request, ReqError> {
    match opt_str(req, "op", "generate")?.as_str() {
        "generate" => Ok(Request::Generate(GenParams {
            variant: opt_str(req, "variant", "")?,
            prompt: opt_str(req, "prompt", "")?,
            image: opt_image(req)?,
            max_tokens: opt_uint(req, "max_tokens", Some(32))?.unwrap_or(32) as usize,
            temperature: opt_num(req, "temperature", 0.0)? as f32,
            seed: opt_uint(req, "seed", Some(0))?.unwrap_or(0),
            stop_token: opt_uint(req, "stop_token", None)?.map(|t| t as i32),
            stream: opt_bool(req, "stream", false)?,
            spec: opt_spec(req)?,
        })),
        "swap" => match req.get("variant") {
            Some(Json::Str(s)) => Ok(Request::Swap { variant: s.clone() }),
            Some(v) => Err(ReqError::field(
                "variant",
                format!("`variant` must be a string, got {}", json_type(v)),
            )),
            None => Err(ReqError::field("variant", "swap requires `variant`".into())),
        },
        "list" => Ok(Request::List),
        "health" => Ok(Request::Health),
        "metrics" => match opt_str(req, "format", "text")?.as_str() {
            "text" => Ok(Request::Metrics { prom: false }),
            "prom" => Ok(Request::Metrics { prom: true }),
            other => Err(ReqError::field(
                "format",
                format!("unknown metrics format `{other}` (expected text or prom)"),
            )),
        },
        "trace" => Ok(Request::Trace { clear: opt_bool(req, "clear", false)? }),
        other => Err(ReqError::field(
            "op",
            format!("unknown op `{other}` (expected generate, swap, list, health, \
                     metrics, or trace)"),
        )),
    }
}

/// Open a decode session for `p`; returns the event stream.
fn open_session(rt: &ServeRuntime, p: &GenParams) -> Result<mpsc::Receiver<GenEvent>> {
    let (etx, erx) = mpsc::channel();
    rt.open(SessionRequest {
        variant: p.variant.clone(),
        prompt: ByteTokenizer.encode(&p.prompt),
        image: p.image.clone(),
        max_tokens: p.max_tokens,
        temperature: p.temperature,
        seed: p.seed,
        stop_token: p.stop_token,
        spec: p.spec.clone(),
        events: etx,
    })
    .map_err(|e| anyhow!("{e}"))?;
    Ok(erx)
}

fn jstr(s: impl Into<String>) -> Json {
    Json::Str(s.into())
}

/// Terminal-line payload shared by every reply shape (streaming terminal
/// line, scheduler one-shot, and the server's engine-fallback one-shot).
/// `timing` is the scheduler's per-request wall-clock breakdown, attached
/// as a `"timing"` object when the path measured one.
pub(crate) fn finish_fields(m: &mut BTreeMap<String, Json>, tokens: &[i32],
                            reason: Option<FinishReason>, latency_s: f64,
                            timing: Option<&RequestTiming>) {
    m.insert("text".into(), jstr(ByteTokenizer.decode(tokens)));
    m.insert("latency_s".into(), Json::Num(latency_s));
    m.insert("tokens_per_s".into(),
             Json::Num(tokens.len() as f64 / latency_s.max(1e-9)));
    m.insert("n_tokens".into(), Json::Num(tokens.len() as f64));
    if let Some(r) = reason {
        m.insert("finish".into(), jstr(r.as_str()));
    }
    if let Some(t) = timing {
        m.insert("timing".into(), t.to_json());
    }
}

/// Stream one generation: a `{"id", "index", "delta", "done": false}` line
/// per token, then the terminal `{"id", "done": true, ...}` line.  A
/// session error becomes an `{"id", "error"}` line (the connection stays
/// usable).  IO errors propagate (client gone).
pub fn run_streaming<W: Write>(rt: &ServeRuntime, p: &GenParams, id: u64,
                               w: &mut W) -> Result<()> {
    let t0 = Instant::now();
    let erx = match open_session(rt, p) {
        Ok(erx) => erx,
        Err(e) => {
            let mut m = BTreeMap::new();
            m.insert("id".into(), Json::Num(id as f64));
            m.insert("error".into(), jstr(format!("{e:#}")));
            writeln!(w, "{}", Json::Obj(m))?;
            w.flush()?;
            return Ok(());
        }
    };
    let tok = ByteTokenizer;
    let mut tokens = Vec::new();
    let mut reason = None;
    let mut error = None;
    let mut timing = None;
    for ev in erx {
        match ev {
            GenEvent::Token { index, token } => {
                tokens.push(token);
                let mut m = BTreeMap::new();
                m.insert("id".into(), Json::Num(id as f64));
                m.insert("index".into(), Json::Num(index as f64));
                m.insert("delta".into(), jstr(tok.decode(&[token])));
                // raw id too: byte-level clients reassembling multi-byte
                // UTF-8 need the token, not the lossy per-byte delta
                m.insert("token".into(), Json::Num(token as f64));
                m.insert("done".into(), Json::Bool(false));
                writeln!(w, "{}", Json::Obj(m))?;
                w.flush()?;
            }
            GenEvent::Done { reason: r, timing: t, .. } => {
                reason = Some(r);
                timing = Some(t);
                break;
            }
            GenEvent::Error(e) => {
                error = Some(e);
                break;
            }
        }
    }
    // A vanished channel without a terminal event (scheduler died) is an
    // error, not a completed stream — mirror run_oneshot's guard.
    if error.is_none() && reason.is_none() {
        error = Some("scheduler dropped the session".into());
    }
    let mut m = BTreeMap::new();
    m.insert("id".into(), Json::Num(id as f64));
    match error {
        Some(e) => {
            m.insert("error".into(), jstr(e));
        }
        None => {
            m.insert("done".into(), Json::Bool(true));
            finish_fields(&mut m, &tokens, reason, t0.elapsed().as_secs_f64(),
                          timing.as_ref());
        }
    }
    writeln!(w, "{}", Json::Obj(m))?;
    w.flush()?;
    Ok(())
}

/// One-shot reply through the scheduler (KV-cached decode, no per-token
/// lines): the legacy `{"text", "latency_s", "tokens_per_s"}` map.
pub fn run_oneshot(rt: &ServeRuntime, p: &GenParams) -> Result<BTreeMap<String, Json>> {
    let t0 = Instant::now();
    let erx = open_session(rt, p)?;
    let mut tokens = Vec::new();
    let mut reason = None;
    let mut timing = None;
    for ev in erx {
        match ev {
            GenEvent::Token { token, .. } => tokens.push(token),
            GenEvent::Done { reason: r, timing: t, .. } => {
                reason = Some(r);
                timing = Some(t);
                break;
            }
            GenEvent::Error(e) => bail!("session failed: {e}"),
        }
    }
    anyhow::ensure!(reason.is_some(), "scheduler dropped the session");
    let mut m = BTreeMap::new();
    finish_fields(&mut m, &tokens, reason, t0.elapsed().as_secs_f64(),
                  timing.as_ref());
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(line: &str) -> GenParams {
        match parse_request(&Json::parse(line).unwrap()).unwrap() {
            Request::Generate(p) => p,
            other => panic!("expected Generate, got {other:?}"),
        }
    }

    fn err(line: &str) -> ReqError {
        parse_request(&Json::parse(line).unwrap()).unwrap_err()
    }

    #[test]
    fn generate_defaults_and_overrides() {
        let p = gen(r#"{"variant": "m/x", "prompt": "hi", "stream": true,
                        "max_tokens": 5, "temperature": 0.5, "seed": 9, "stop_token": 10}"#);
        assert_eq!(p.variant, "m/x");
        assert_eq!(p.prompt, "hi");
        assert!(p.stream);
        assert_eq!(p.max_tokens, 5);
        assert_eq!(p.seed, 9);
        assert_eq!(p.stop_token, Some(10));
        assert!((p.temperature - 0.5).abs() < 1e-6);

        // op-less line == generate, historical defaults intact (the
        // pre-registry wire contract)
        let p = gen(r#"{"variant": "m/x", "prompt": ""}"#);
        assert!(!p.stream);
        assert_eq!(p.max_tokens, 32);
        assert_eq!(p.stop_token, None);
        assert_eq!(p.image, None);
        assert_eq!(p.spec, None);
        // explicit op spells the same thing
        let p = gen(r#"{"op": "generate", "prompt": "x"}"#);
        assert_eq!(p.prompt, "x");
        assert_eq!(p.variant, "");
    }

    #[test]
    fn control_ops_parse() {
        assert!(matches!(parse_request(&Json::parse(r#"{"op": "list"}"#).unwrap()),
                         Ok(Request::List)));
        assert!(matches!(parse_request(&Json::parse(r#"{"op": "health"}"#).unwrap()),
                         Ok(Request::Health)));
        match parse_request(&Json::parse(r#"{"op": "swap", "variant": "m/x"}"#).unwrap()) {
            Ok(Request::Swap { variant }) => assert_eq!(variant, "m/x"),
            other => panic!("expected Swap, got {other:?}"),
        }
    }

    #[test]
    fn metrics_and_trace_ops_parse_with_typed_options() {
        assert!(matches!(parse_request(&Json::parse(r#"{"op": "metrics"}"#).unwrap()),
                         Ok(Request::Metrics { prom: false })));
        assert!(matches!(
            parse_request(&Json::parse(r#"{"op": "metrics", "format": "text"}"#).unwrap()),
            Ok(Request::Metrics { prom: false })));
        assert!(matches!(
            parse_request(&Json::parse(r#"{"op": "metrics", "format": "prom"}"#).unwrap()),
            Ok(Request::Metrics { prom: true })));
        let e = err(r#"{"op": "metrics", "format": "xml"}"#);
        assert_eq!(e.field.as_deref(), Some("format"));
        assert!(e.msg.contains("xml"), "{}", e.msg);
        let e = err(r#"{"op": "metrics", "format": 7}"#);
        assert_eq!(e.field.as_deref(), Some("format"));

        assert!(matches!(parse_request(&Json::parse(r#"{"op": "trace"}"#).unwrap()),
                         Ok(Request::Trace { clear: false })));
        assert!(matches!(
            parse_request(&Json::parse(r#"{"op": "trace", "clear": true}"#).unwrap()),
            Ok(Request::Trace { clear: true })));
        let e = err(r#"{"op": "trace", "clear": "yes"}"#);
        assert_eq!(e.field.as_deref(), Some("clear"));
    }

    #[test]
    fn malformed_fields_error_naming_the_field() {
        let e = err(r#"{"op": "teleport"}"#);
        assert_eq!(e.field.as_deref(), Some("op"));
        assert!(e.msg.contains("teleport"), "{}", e.msg);

        let e = err(r#"{"op": "swap"}"#);
        assert_eq!(e.field.as_deref(), Some("variant"));

        let e = err(r#"{"op": "swap", "variant": 7}"#);
        assert_eq!(e.field.as_deref(), Some("variant"));

        let e = err(r#"{"prompt": "x", "max_tokens": "32"}"#);
        assert_eq!(e.field.as_deref(), Some("max_tokens"));
        assert!(e.msg.contains("string"), "{}", e.msg);

        let e = err(r#"{"prompt": "x", "max_tokens": -3}"#);
        assert_eq!(e.field.as_deref(), Some("max_tokens"));

        let e = err(r#"{"prompt": "x", "max_tokens": 2.5}"#);
        assert_eq!(e.field.as_deref(), Some("max_tokens"));

        let e = err(r#"{"variant": ["m/x"]}"#);
        assert_eq!(e.field.as_deref(), Some("variant"));

        let e = err(r#"{"stream": "yes"}"#);
        assert_eq!(e.field.as_deref(), Some("stream"));

        let e = err(r#"{"temperature": "hot"}"#);
        assert_eq!(e.field.as_deref(), Some("temperature"));

        // explicit null == absent, not a type error
        let p = gen(r#"{"prompt": "x", "stop_token": null}"#);
        assert_eq!(p.stop_token, None);
    }

    #[test]
    fn image_field_parses_and_type_errors_name_the_field() {
        let p = gen(r#"{"prompt": "x", "image": [0.5, -1.25, 3]}"#);
        assert_eq!(p.image, Some(vec![0.5f32, -1.25, 3.0]));
        let p = gen(r#"{"prompt": "x", "image": null}"#);
        assert_eq!(p.image, None);

        let e = err(r#"{"prompt": "x", "image": "pixels"}"#);
        assert_eq!(e.field.as_deref(), Some("image"));
        assert!(e.msg.contains("array"), "{}", e.msg);

        // the offending element is named by index
        let e = err(r#"{"prompt": "x", "image": [1.0, "two"]}"#);
        assert_eq!(e.field.as_deref(), Some("image"));
        assert!(e.msg.contains("image[1]"), "{}", e.msg);
    }

    #[test]
    fn spec_field_parses_with_default_k_and_typed_errors() {
        let p = gen(r#"{"prompt": "x", "spec": {"draft": "tiny/dobi_30", "k": 8}}"#);
        assert_eq!(p.spec, Some(SpecParams { draft: "tiny/dobi_30".into(), k: 8 }));
        // k defaults to 4
        let p = gen(r#"{"prompt": "x", "spec": {"draft": "tiny/dobi_30"}}"#);
        assert_eq!(p.spec, Some(SpecParams { draft: "tiny/dobi_30".into(), k: 4 }));

        let e = err(r#"{"prompt": "x", "spec": "tiny/dobi_30"}"#);
        assert_eq!(e.field.as_deref(), Some("spec"));
        assert!(e.msg.contains("object"), "{}", e.msg);

        let e = err(r#"{"prompt": "x", "spec": {}}"#);
        assert_eq!(e.field.as_deref(), Some("spec.draft"));
        let e = err(r#"{"prompt": "x", "spec": {"draft": ""}}"#);
        assert_eq!(e.field.as_deref(), Some("spec.draft"));
        let e = err(r#"{"prompt": "x", "spec": {"draft": 7}}"#);
        assert_eq!(e.field.as_deref(), Some("spec.draft"));

        let e = err(r#"{"prompt": "x", "spec": {"draft": "d", "k": 0}}"#);
        assert_eq!(e.field.as_deref(), Some("spec.k"));
        let e = err(r#"{"prompt": "x", "spec": {"draft": "d", "k": 2.5}}"#);
        assert_eq!(e.field.as_deref(), Some("spec.k"));
        let e = err(r#"{"prompt": "x", "spec": {"draft": "d", "k": "four"}}"#);
        assert_eq!(e.field.as_deref(), Some("spec.k"));
    }
}
