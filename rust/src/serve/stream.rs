//! Token streaming over the existing TCP line protocol.
//!
//! Request (one JSON object per line, same as the one-shot path, plus the
//! `stream` switch):
//!   -> {"variant": "tiny/dobi_40", "prompt": "The ", "max_tokens": 32,
//!       "temperature": 0.0, "stream": true, "stop_token": 10}
//!
//! Streaming reply: one line per generated token, then a terminal line —
//!   <- {"id": 1, "index": 0, "delta": "t", "token": 116, "done": false}
//!   <- ...
//!   <- {"id": 1, "done": true, "text": "the...", "n_tokens": 32,
//!       "finish": "max_tokens", "latency_s": 0.01, "tokens_per_s": 3200.0}
//!
//! Without `"stream": true` the reply is the single legacy object
//! (`{"id", "text", "latency_s", "tokens_per_s"}`), but still decoded
//! incrementally through the scheduler when it serves the variant.
//!
//! Deltas are per-token byte decodes: a multi-byte UTF-8 character split
//! across tokens renders as replacement characters in the deltas; the
//! terminal line's `text` is the lossless whole-stream decode clients
//! should reconcile against.

use std::collections::BTreeMap;
use std::io::Write;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::json::Json;
use crate::tokenizer::ByteTokenizer;

use super::scheduler::{FinishReason, GenEvent, ServeRuntime, SessionRequest};

/// Generation parameters shared by the streaming and one-shot paths.
#[derive(Debug, Clone)]
pub struct GenParams {
    pub variant: String,
    pub prompt: String,
    pub max_tokens: usize,
    pub temperature: f32,
    pub seed: u64,
    pub stop_token: Option<i32>,
    pub stream: bool,
}

/// Pull the generation fields out of a parsed request line.  Missing
/// `variant`/`prompt` become empty strings — the open/serve path then
/// answers a proper error line instead of panicking the handler.
pub fn parse_params(req: &Json) -> GenParams {
    GenParams {
        variant: req.get("variant").and_then(Json::as_str).unwrap_or_default().to_string(),
        prompt: req.get("prompt").and_then(Json::as_str).unwrap_or_default().to_string(),
        max_tokens: req.get("max_tokens").and_then(Json::as_usize).unwrap_or(32),
        temperature: req.get("temperature").and_then(Json::as_f64).unwrap_or(0.0) as f32,
        seed: req.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        stop_token: req.get("stop_token").and_then(Json::as_usize).map(|t| t as i32),
        stream: req.get("stream").and_then(Json::as_bool).unwrap_or(false),
    }
}

/// Open a decode session for `p`; returns the event stream.
fn open_session(rt: &ServeRuntime, p: &GenParams) -> Result<mpsc::Receiver<GenEvent>> {
    let (etx, erx) = mpsc::channel();
    rt.open(SessionRequest {
        variant: p.variant.clone(),
        prompt: ByteTokenizer.encode(&p.prompt),
        image: None,
        max_tokens: p.max_tokens,
        temperature: p.temperature,
        seed: p.seed,
        stop_token: p.stop_token,
        events: etx,
    })
    .map_err(|e| anyhow!("{e}"))?;
    Ok(erx)
}

fn jstr(s: impl Into<String>) -> Json {
    Json::Str(s.into())
}

/// Terminal-line payload shared by every reply shape (streaming terminal
/// line, scheduler one-shot, and the server's engine-fallback one-shot).
pub(crate) fn finish_fields(m: &mut BTreeMap<String, Json>, tokens: &[i32],
                            reason: Option<FinishReason>, latency_s: f64) {
    m.insert("text".into(), jstr(ByteTokenizer.decode(tokens)));
    m.insert("latency_s".into(), Json::Num(latency_s));
    m.insert("tokens_per_s".into(),
             Json::Num(tokens.len() as f64 / latency_s.max(1e-9)));
    m.insert("n_tokens".into(), Json::Num(tokens.len() as f64));
    if let Some(r) = reason {
        m.insert("finish".into(), jstr(r.as_str()));
    }
}

/// Stream one generation: a `{"id", "index", "delta", "done": false}` line
/// per token, then the terminal `{"id", "done": true, ...}` line.  A
/// session error becomes an `{"id", "error"}` line (the connection stays
/// usable).  IO errors propagate (client gone).
pub fn run_streaming<W: Write>(rt: &ServeRuntime, p: &GenParams, id: u64,
                               w: &mut W) -> Result<()> {
    let t0 = Instant::now();
    let erx = match open_session(rt, p) {
        Ok(erx) => erx,
        Err(e) => {
            let mut m = BTreeMap::new();
            m.insert("id".into(), Json::Num(id as f64));
            m.insert("error".into(), jstr(format!("{e:#}")));
            writeln!(w, "{}", Json::Obj(m))?;
            w.flush()?;
            return Ok(());
        }
    };
    let tok = ByteTokenizer;
    let mut tokens = Vec::new();
    let mut reason = None;
    let mut error = None;
    for ev in erx {
        match ev {
            GenEvent::Token { index, token } => {
                tokens.push(token);
                let mut m = BTreeMap::new();
                m.insert("id".into(), Json::Num(id as f64));
                m.insert("index".into(), Json::Num(index as f64));
                m.insert("delta".into(), jstr(tok.decode(&[token])));
                // raw id too: byte-level clients reassembling multi-byte
                // UTF-8 need the token, not the lossy per-byte delta
                m.insert("token".into(), Json::Num(token as f64));
                m.insert("done".into(), Json::Bool(false));
                writeln!(w, "{}", Json::Obj(m))?;
                w.flush()?;
            }
            GenEvent::Done { reason: r, .. } => {
                reason = Some(r);
                break;
            }
            GenEvent::Error(e) => {
                error = Some(e);
                break;
            }
        }
    }
    // A vanished channel without a terminal event (scheduler died) is an
    // error, not a completed stream — mirror run_oneshot's guard.
    if error.is_none() && reason.is_none() {
        error = Some("scheduler dropped the session".into());
    }
    let mut m = BTreeMap::new();
    m.insert("id".into(), Json::Num(id as f64));
    match error {
        Some(e) => {
            m.insert("error".into(), jstr(e));
        }
        None => {
            m.insert("done".into(), Json::Bool(true));
            finish_fields(&mut m, &tokens, reason, t0.elapsed().as_secs_f64());
        }
    }
    writeln!(w, "{}", Json::Obj(m))?;
    w.flush()?;
    Ok(())
}

/// One-shot reply through the scheduler (KV-cached decode, no per-token
/// lines): the legacy `{"text", "latency_s", "tokens_per_s"}` map.
pub fn run_oneshot(rt: &ServeRuntime, p: &GenParams) -> Result<BTreeMap<String, Json>> {
    let t0 = Instant::now();
    let erx = open_session(rt, p)?;
    let mut tokens = Vec::new();
    let mut reason = None;
    for ev in erx {
        match ev {
            GenEvent::Token { token, .. } => tokens.push(token),
            GenEvent::Done { reason: r, .. } => {
                reason = Some(r);
                break;
            }
            GenEvent::Error(e) => bail!("session failed: {e}"),
        }
    }
    anyhow::ensure!(reason.is_some(), "scheduler dropped the session");
    let mut m = BTreeMap::new();
    finish_fields(&mut m, &tokens, reason, t0.elapsed().as_secs_f64());
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_params_defaults_and_overrides() {
        let req = Json::parse(
            r#"{"variant": "m/x", "prompt": "hi", "stream": true,
                "max_tokens": 5, "temperature": 0.5, "seed": 9, "stop_token": 10}"#,
        )
        .unwrap();
        let p = parse_params(&req);
        assert_eq!(p.variant, "m/x");
        assert_eq!(p.prompt, "hi");
        assert!(p.stream);
        assert_eq!(p.max_tokens, 5);
        assert_eq!(p.seed, 9);
        assert_eq!(p.stop_token, Some(10));
        assert!((p.temperature - 0.5).abs() < 1e-6);

        let bare = Json::parse(r#"{"variant": "m/x", "prompt": ""}"#).unwrap();
        let p = parse_params(&bare);
        assert!(!p.stream);
        assert_eq!(p.max_tokens, 32);
        assert_eq!(p.stop_token, None);
    }
}
