//! Incremental decode runtime: per-session KV caches, continuous
//! batching, token streaming.
//!
//! The serving win Dobi-SVD promises — rank-truncated factors making each
//! *token* cheaper — only materializes with decode state: the old path
//! re-ran a full sliding-window forward per generated token, recomputing
//! O(len²) attention and a (len, vocab) logits head every step.  This
//! subsystem replaces that loop:
//!
//! * [`session`]   — [`session::DecodeSession`]: one request's prefill /
//!   step lifecycle over a preallocated per-layer KV cache
//!   ([`crate::lowrank::model::KvCache`]), each step O(len) attention over
//!   cached state plus a single-row logits head.
//! * [`scheduler`] — [`scheduler::ServeRuntime`]: a continuous-batching
//!   scheduler thread that owns the loaded models, admits sessions
//!   mid-flight (FIFO-fair via the coordinator's [`DynamicBatcher`]
//!   grouping), advances each tick's live sessions per variant through
//!   ONE fused multi-session trunk walk
//!   ([`crate::lowrank::FactorizedModel::forward_kv_multi`] — weight
//!   tiles dequantize once per tick, not once per session; bit-identical
//!   to serial stepping), and evicts on stop-token / `max_tokens` / KV
//!   capacity.
//! * [`registry`]  — [`registry::VariantRegistry`]: the live variant
//!   table.  Each variant serves an `Arc`-held, hash-verified
//!   [`registry::ModelRelease`]; `{"op":"swap"}` installs a new
//!   generation while in-flight sessions drain on the old one, which is
//!   garbage-collected after its last session finishes.
//! * [`spec`]      — [`spec::SpecDecoder`]: self-speculative decoding.
//!   An aggressive low-rank variant drafts `k` tokens from its own KV
//!   cache; the session's target variant verifies all of them in ONE
//!   batched multi-row trunk walk
//!   ([`crate::lowrank::FactorizedModel::forward_kv_rows`]), accepts the
//!   matching prefix, corrects the first mismatch from its own logits,
//!   and rolls rejected rows back
//!   ([`crate::lowrank::model::KvCache::truncate_to`]).  Greedy output is
//!   byte-identical to pure target decode; the acceptance rate doubles as
//!   a serving-native measure of how much dense behavior the draft's SVD
//!   ratio preserves.
//! * [`stream`]    — the typed [`stream::Request`] protocol parsed off
//!   the TCP line framing (generate / swap / list / health / metrics /
//!   trace), the `{"id", "delta", "done"}` token-streaming framing
//!   (`"stream": true`), plus the scheduler-backed one-shot reply.
//!
//! The whole request lifecycle is instrumented through [`crate::trace`]:
//! the scheduler records queue-wait / admission / prefill / step /
//! fused-step / spec-draft / spec-verify / eviction spans into the
//! runtime's [`crate::trace::TraceBuffer`] (drained by `{"op":"trace"}`
//! as Perfetto-loadable JSON), exports labeled
//! `serve_*{variant=..,reason=..}` metric families through
//! [`crate::metrics`], and delivers a per-request
//! [`crate::trace::RequestTiming`] summary on every `Done` — the
//! `"timing"` object clients see.
//!
//! [`DynamicBatcher`]: crate::coordinator::DynamicBatcher

pub mod registry;
pub mod scheduler;
pub mod session;
pub mod spec;
pub mod stream;

pub use registry::{ModelRelease, VariantRegistry, VariantStatus};
pub use scheduler::{FinishReason, GenEvent, ServeRuntime, ServeStats, SessionRequest};
pub use session::DecodeSession;
pub use spec::{SpecDecoder, SpecParams, SpecRound};
pub use stream::{GenParams, ReqError, Request};
