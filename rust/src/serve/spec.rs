//! Self-speculative decoding: a compressed low-rank variant drafts, the
//! dense (or high-ratio) target verifies.
//!
//! The paper's claim — activation-truncated variants keep most of the
//! dense model's behavior — becomes a serving accelerator here: an
//! aggressive draft variant (e.g. ratio 0.3) autoregressively proposes
//! `k` tokens from its own KV cache, then the target checks all of them
//! in ONE batched multi-row trunk walk
//! ([`crate::lowrank::FactorizedModel::forward_kv_rows`]).  Accepted
//! rows advance both caches; the first mismatch is corrected from the
//! target's own logits; rejected rows are rolled back
//! ([`crate::lowrank::model::KvCache::truncate_to`]).
//!
//! **Parity guarantee:** every emitted token is the argmax of a TARGET
//! logits row, and those rows are bit-identical to what serial
//! single-token target decode would compute (the multi-row step shares
//! the serial step's kernels and the blocked GEMM is row-independent).
//! Greedy speculative output is therefore byte-identical to pure target
//! decode — the draft only decides how many target rows each walk
//! amortizes.  Acceptance rate, in turn, is a serving-native measurement
//! of how much of the dense greedy distribution survives SVD truncation
//! at the draft's ratio (BENCH_spec.json records the curve).
//!
//! The scheduler drives one [`SpecDecoder::round`] per tick for each
//! speculative session, then pushes the returned target rows through its
//! normal emit gate (stop token / budget / capacity), so speculative and
//! plain sessions share every termination and streaming path.  The
//! round's [`SpecRound::draft_s`]/[`SpecRound::verify_s`] phase wall
//! times are what the scheduler turns into `spec_draft`/`spec_verify`
//! spans in [`crate::trace`] and the `draft_us`/`verify_us` fields of
//! the client-visible `"timing"` summary — the phases run back to back
//! inside the round, so the spans are reconstructed from these numbers
//! rather than re-timed.

use anyhow::Result;

use crate::lowrank::FactorizedModel;
use crate::mathx::argmax;

use super::session::DecodeSession;

/// Client-requested speculative parameters: the protocol's
/// `"spec": {"draft": ..., "k": ...}` generate field (or the server's
/// `--spec-draft`/`--spec-k` defaults) after validation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpecParams {
    /// Variant id drafting for this session's target variant.
    pub draft: String,
    /// Tokens drafted per round.
    pub k: usize,
}

/// Outcome of one draft/verify round.
pub struct SpecRound {
    /// Target logits rows `R_0..R_a`, one per token to emit: row `i` is
    /// the target's logits after the round's input token plus `i`
    /// accepted candidates, so greedily emitting `argmax(rows[i])` in
    /// order reproduces pure target decode — the accepted candidates
    /// first, then the correction (or bonus) token from the last row.
    pub rows: Vec<Vec<f32>>,
    /// Candidates the draft proposed this round (`<= k`; clipped by the
    /// target cache's remaining capacity).
    pub proposed: usize,
    /// Length of the accepted candidate prefix (`<= proposed`).
    pub accepted: usize,
    /// Wall time of the draft phase (catch-up + autoregressive drafting).
    pub draft_s: f64,
    /// Wall time of the verify phase (one batched multi-row target walk).
    pub verify_s: f64,
}

/// Draft-side state paired with one target [`DecodeSession`]: the
/// draft's own session (same prompt, own KV cache) plus the committed
/// tokens the draft has not attended yet.
pub struct SpecDecoder {
    draft: DecodeSession,
    k: usize,
    /// Committed target tokens missing from the draft cache — after a
    /// fully-accepted round the final candidate was never fed to the
    /// draft, so it catches up at the start of the next round.
    pending: Vec<i32>,
}

impl SpecDecoder {
    /// Pair a prefilled draft session with a target.  `k` is the number
    /// of tokens drafted per round (>= 1).
    pub fn new(draft: DecodeSession, k: usize) -> SpecDecoder {
        SpecDecoder { draft, k: k.max(1), pending: Vec::new() }
    }

    /// The draft session's variant id (hot-swap drain checks).
    pub fn draft_variant(&self) -> &str {
        &self.draft.variant
    }

    /// Host bytes the draft cache pins (KV accounting counts the pair).
    pub fn draft_kv_bytes(&self) -> usize {
        self.draft.kv_bytes()
    }

    /// One draft/verify round.  `last` is the most recently emitted
    /// token, not yet attended by either cache (the same contract as the
    /// plain path's `step(last)`).  On return the target cache holds
    /// `last` plus the accepted candidate prefix, the draft cache is
    /// consistent with it, and `rows` yields `accepted + 1` emissions.
    ///
    /// On `Err` the pair may hold partially-advanced caches — callers
    /// terminate the session, exactly like a failed plain step.
    pub fn round(&mut self, draft_model: &FactorizedModel, target_model: &FactorizedModel,
                 target: &mut DecodeSession, last: i32) -> Result<SpecRound> {
        // The verify step appends 1 + k rows; clip k to what the target
        // cache can still hold (k_round == 0 degenerates to a plain
        // single-row step — the session is about to hit Length anyway).
        let k_round = self.k.min(target.remaining().saturating_sub(1));

        // Draft phase: catch up on pending committed tokens + `last` in
        // one multi-token step, then draft autoregressively.  The final
        // candidate is never fed (its logits are never needed).
        let t_draft = std::time::Instant::now();
        let mut cands: Vec<i32> = Vec::with_capacity(k_round);
        if k_round == 0 {
            self.pending.push(last);
        } else {
            let mut feed = std::mem::take(&mut self.pending);
            feed.push(last);
            let dv = draft_model.vocab;
            let rows = self.draft.verify_rows(draft_model, &feed)?;
            let mut logits = rows[(feed.len() - 1) * dv..].to_vec();
            for _ in 0..k_round {
                let c = argmax(&logits) as i32;
                cands.push(c);
                if cands.len() < k_round {
                    logits = self.draft.step(draft_model, c)?;
                }
            }
        }

        let draft_s = t_draft.elapsed().as_secs_f64();

        // Verify phase: ONE batched multi-row target walk over `last`
        // plus every candidate.  Row i is bit-identical to the serial
        // target step after `last, cands[..i]`.
        let t_verify = std::time::Instant::now();
        let base = target.positions();
        let mut vtoks = Vec::with_capacity(1 + cands.len());
        vtoks.push(last);
        vtoks.extend_from_slice(&cands);
        let flat = target.verify_rows(target_model, &vtoks)?;
        let tv = target_model.vocab;

        // Accept the longest prefix the target would have emitted itself.
        let mut a = 0usize;
        while a < cands.len() && argmax(&flat[a * tv..(a + 1) * tv]) as i32 == cands[a] {
            a += 1;
        }

        // Rollback: the target keeps `last` + the accepted prefix; the
        // draft keeps the same context minus any candidate it never fed.
        target.rollback_to(base + 1 + a);
        if k_round > 0 {
            if a < k_round {
                self.draft.rollback_to(base + 1 + a);
            } else {
                // fully accepted: the draft never attended the final
                // candidate — it becomes next round's catch-up token
                self.pending.push(cands[k_round - 1]);
            }
        }

        let rows = flat[..(a + 1) * tv].chunks_exact(tv).map(<[f32]>::to_vec).collect();
        Ok(SpecRound {
            rows,
            proposed: k_round,
            accepted: a,
            draft_s,
            verify_s: t_verify.elapsed().as_secs_f64(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowrank::synth::{tiny_model, TinyDims};

    fn dims() -> TinyDims {
        TinyDims { vocab: 61, d: 16, heads: 2, layers: 2, ff: 24 }
    }

    /// Pure greedy target decode — the byte-parity reference.
    fn pure_decode(m: &FactorizedModel, prompt: &[i32], n: usize, cap: usize) -> Vec<i32> {
        let mut s = DecodeSession::new(1, "ref", m, cap);
        let mut logits = s.prefill(m, prompt, None).unwrap();
        let mut out = Vec::new();
        while out.len() < n {
            let t = argmax(&logits) as i32;
            out.push(t);
            if out.len() < n {
                logits = s.step(m, t).unwrap();
            }
        }
        out
    }

    /// Greedy speculative decode with `draft_m` drafting for `target_m`.
    fn spec_decode(target_m: &FactorizedModel, draft_m: &FactorizedModel, prompt: &[i32],
                   n: usize, k: usize, cap: usize) -> (Vec<i32>, usize, usize) {
        let mut target = DecodeSession::new(1, "tgt", target_m, cap);
        let logits = target.prefill(target_m, prompt, None).unwrap();
        let mut draft = DecodeSession::new(2, "dft", draft_m, cap);
        draft.prefill(draft_m, prompt, None).unwrap();
        let mut spec = SpecDecoder::new(draft, k);
        let mut out = vec![argmax(&logits) as i32];
        let (mut proposed, mut accepted) = (0usize, 0usize);
        'outer: while out.len() < n {
            let last = *out.last().unwrap();
            let r = spec.round(draft_m, target_m, &mut target, last).unwrap();
            proposed += r.proposed;
            accepted += r.accepted;
            for row in &r.rows {
                out.push(argmax(row) as i32);
                if out.len() >= n {
                    break 'outer;
                }
            }
        }
        (out, proposed, accepted)
    }

    #[test]
    fn greedy_spec_decode_bit_identical_to_pure_target_decode() {
        let target = tiny_model(dims(), 0, false);
        // full-rank factorized weights: close to the dense target but not
        // identical logits — candidates genuinely get rejected sometimes
        let draft = tiny_model(dims(), 0, true);
        for (pi, prompt) in [vec![1i32, 2, 3], (0..9).map(|i| (i * 11) % 61).collect(),
                             vec![42]].into_iter().enumerate() {
            let want = pure_decode(&target, &prompt, 24, 64);
            for k in [1usize, 2, 4, 8] {
                let (got, proposed, accepted) =
                    spec_decode(&target, &draft, &prompt, 24, k, 64);
                assert_eq!(got, want,
                           "spec decode diverged (prompt {pi}, k {k}, \
                            accepted {accepted}/{proposed})");
                assert!(accepted <= proposed);
            }
        }
    }

    #[test]
    fn self_drafting_accepts_everything() {
        // The target drafting for itself proposes its own argmax chain:
        // every candidate must be accepted (the degenerate upper bound).
        let m = tiny_model(dims(), 0, false);
        let prompt = vec![5i32, 6, 7];
        let want = pure_decode(&m, &prompt, 20, 64);
        let (got, proposed, accepted) = spec_decode(&m, &m, &prompt, 20, 4, 64);
        assert_eq!(got, want);
        assert!(proposed > 0);
        assert_eq!(accepted, proposed, "self-drafting must accept every candidate");
    }

    #[test]
    fn capacity_clips_the_draft_window() {
        // cap 12, prompt 8: rounds near the cache edge must clip k and
        // still match pure decode token-for-token until capacity.
        let target = tiny_model(dims(), 0, false);
        let draft = tiny_model(dims(), 0, true);
        let prompt: Vec<i32> = (0..8).map(|i| (i * 7 + 1) % 61).collect();
        let cap = 12;
        // pure decode can emit cap - prompt + 1 = 5 tokens before the
        // final step would overflow
        let want = pure_decode(&target, &prompt, 5, cap);
        let (got, _, _) = spec_decode(&target, &draft, &prompt, 5, 8, cap);
        assert_eq!(got, want, "capacity-clipped spec decode diverged");
    }

    #[test]
    fn round_reports_rows_matching_acceptance() {
        let target = tiny_model(dims(), 0, false);
        let draft_m = tiny_model(dims(), 0, true);
        let prompt = vec![9i32, 8, 7];
        let mut tgt = DecodeSession::new(1, "tgt", &target, 64);
        let logits = tgt.prefill(&target, &prompt, None).unwrap();
        let mut dft = DecodeSession::new(2, "dft", &draft_m, 64);
        dft.prefill(&draft_m, &prompt, None).unwrap();
        let mut spec = SpecDecoder::new(dft, 4);
        let base = tgt.positions();
        let r = spec.round(&draft_m, &target, &mut tgt, argmax(&logits) as i32).unwrap();
        assert_eq!(r.rows.len(), r.accepted + 1);
        assert_eq!(r.proposed, 4);
        // the target cache holds the input token + the accepted prefix
        assert_eq!(tgt.positions(), base + 1 + r.accepted);
        for row in &r.rows {
            assert_eq!(row.len(), target.vocab);
        }
    }
}
