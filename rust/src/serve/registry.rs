//! Live variant table behind the decode scheduler: which release of each
//! variant serves new sessions, which superseded releases are still
//! draining, and the provenance of every one of them.
//!
//! A **release** is one verified load of a variant's weights
//! ([`ModelRelease`]): the model, the generation number, and the content
//! hash the manifest pinned.  The registry owns the current release per
//! variant; every admitted session holds an `Arc` to the release it
//! decodes against.  A hot swap ([`VariantRegistry::install`]) replaces
//! the current release — new admissions route to the new generation
//! immediately, in-flight sessions keep decoding on the old `Arc` until
//! they finish (drain), and [`VariantRegistry::sweep`] garbage-collects a
//! drained release the moment the registry holds its last reference.
//! Nothing is ever torn out from under a session: correctness comes from
//! `Arc` ownership, not locks around the decode loop.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::config::Manifest;
use crate::lowrank::FactorizedModel;

/// One immutable, verified load of a variant's weights.
pub struct ModelRelease {
    pub variant: String,
    /// Monotonic per-variant install counter (1 = initial load).
    pub generation: u64,
    pub model: FactorizedModel,
    /// Content hash the manifest pinned (`None` on pre-provenance
    /// manifests, which load unverified).
    pub store_sha256: Option<String>,
    /// Rank-allocation mode recorded in the manifest.
    pub alloc: String,
    /// Achieved stored-parameter ratio recorded in the manifest.
    pub ratio: f64,
}

/// A variant's weights loaded through the verified manifest path, not yet
/// assigned a generation — what [`VariantRegistry::install`] consumes.
pub struct LoadedVariant {
    pub model: FactorizedModel,
    pub store_sha256: Option<String>,
    pub alloc: String,
    pub ratio: f64,
}

/// Load one variant as an incrementally-servable native model, verifying
/// the store's content hashes against the manifest's provenance pin
/// ([`Manifest::open_store`]).  Every release the registry ever holds
/// comes through here — there is no unverified side door.
pub fn load_release(manifest: &Manifest, id: &str) -> Result<LoadedVariant> {
    let v = manifest.variant(id)?;
    let info = manifest
        .models
        .get(&v.model)
        .ok_or_else(|| anyhow!("model `{}` missing from manifest", v.model))?;
    let store = manifest.open_store(v)?;
    let model = FactorizedModel::from_store(info, v, &store)?;
    anyhow::ensure!(!model.action_head, "VLA variants have no token stream to decode");
    Ok(LoadedVariant {
        model,
        store_sha256: v.provenance.as_ref().map(|p| p.store_sha256.clone()),
        alloc: v.alloc.clone(),
        ratio: v.ratio,
    })
}

struct Slot {
    current: Arc<ModelRelease>,
    /// Superseded releases still referenced by in-flight sessions (or
    /// awaiting the next sweep).
    draining: Vec<Arc<ModelRelease>>,
}

/// Point-in-time view of one variant's slot — what `{"op":"list"}` and
/// `dobi inspect` render.
#[derive(Debug, Clone)]
pub struct VariantStatus {
    pub variant: String,
    pub generation: u64,
    pub store_sha256: Option<String>,
    pub alloc: String,
    pub ratio: f64,
    /// Sessions currently holding the live release.
    pub active_sessions: usize,
    /// Superseded generations still draining, with their session counts.
    pub draining: Vec<(u64, usize)>,
}

/// The live variant table.  Shared between the scheduler thread (admission
/// + sweep) and server control handlers (swap/list) behind a mutex; the
/// lock guards only the table itself — decode steps run on `Arc`-held
/// releases outside it.
#[derive(Default)]
pub struct VariantRegistry {
    slots: BTreeMap<String, Slot>,
}

/// Can `draft` propose tokens for `target`?  Speculative decode requires
/// the pair to agree on every dimension a token stream flows through —
/// same vocab (and the byte tokenizer is universal here), same trunk
/// geometry, same image-prefix shape — so the draft's candidates and the
/// target's verify rows index the same distribution.  Ranks and stored
/// precision are exactly what MAY differ: that is the compression.
pub fn spec_compatible(draft: &FactorizedModel, target: &FactorizedModel) -> Result<()> {
    anyhow::ensure!(!draft.action_head && !target.action_head,
                    "VLA variants have no token stream to speculate on");
    let same = draft.vocab == target.vocab
        && draft.d_model == target.d_model
        && draft.n_heads == target.n_heads
        && draft.d_ff == target.d_ff
        && draft.layers.len() == target.layers.len()
        && draft.img_dim == target.img_dim
        && draft.n_img_tokens == target.n_img_tokens;
    anyhow::ensure!(
        same,
        "draft `{}` (vocab {}, d {}, heads {}, ff {}, layers {}, img {}x{}) is not \
         shape-compatible with target `{}` (vocab {}, d {}, heads {}, ff {}, layers {}, \
         img {}x{})",
        draft.id, draft.vocab, draft.d_model, draft.n_heads, draft.d_ff, draft.layers.len(),
        draft.img_dim, draft.n_img_tokens,
        target.id, target.vocab, target.d_model, target.n_heads, target.d_ff,
        target.layers.len(), target.img_dim, target.n_img_tokens
    );
    Ok(())
}

impl VariantRegistry {
    /// The release new sessions for `variant` should decode against.
    pub fn current(&self, variant: &str) -> Option<Arc<ModelRelease>> {
        self.slots.get(variant).map(|s| s.current.clone())
    }

    /// Resolve a speculative draft for `target`'s release: the draft
    /// variant's CURRENT release, checked for shape compatibility
    /// ([`spec_compatible`]).  Errors name the offending variant so the
    /// client's typed error is actionable.
    pub fn resolve_draft(&self, draft_variant: &str,
                         target: &ModelRelease) -> Result<Arc<ModelRelease>> {
        let draft = self
            .current(draft_variant)
            .ok_or_else(|| anyhow!("unknown draft variant `{draft_variant}`"))?;
        spec_compatible(&draft.model, &target.model)?;
        Ok(draft)
    }

    pub fn variants(&self) -> Vec<String> {
        self.slots.keys().cloned().collect()
    }

    pub fn has(&self, variant: &str) -> bool {
        self.slots.contains_key(variant)
    }

    /// Install a freshly loaded release as `variant`'s current one and
    /// return its generation.  An existing current release moves to the
    /// draining list — sessions holding it are untouched; new admissions
    /// see the new generation from this call on.
    pub fn install(&mut self, variant: &str, loaded: LoadedVariant) -> u64 {
        let (generation, drained) = match self.slots.remove(variant) {
            Some(slot) => {
                let gen = slot.current.generation + 1;
                let mut draining = slot.draining;
                draining.push(slot.current);
                (gen, draining)
            }
            None => (1, Vec::new()),
        };
        let release = Arc::new(ModelRelease {
            variant: variant.to_string(),
            generation,
            model: loaded.model,
            store_sha256: loaded.store_sha256,
            alloc: loaded.alloc,
            ratio: loaded.ratio,
        });
        self.slots.insert(variant.to_string(), Slot { current: release, draining: drained });
        generation
    }

    /// Drop draining releases no session references anymore (the registry
    /// holds the last `Arc`) and return how many were freed.  Called by
    /// the scheduler after each tick's evictions — the GC point where a
    /// superseded store's memory is actually released.
    pub fn sweep(&mut self) -> usize {
        let mut freed = 0;
        for slot in self.slots.values_mut() {
            let before = slot.draining.len();
            slot.draining.retain(|r| Arc::strong_count(r) > 1);
            freed += before - slot.draining.len();
        }
        freed
    }

    /// Total in-flight sessions still pinned to superseded releases.
    pub fn draining_sessions(&self) -> usize {
        self.slots
            .values()
            .flat_map(|s| &s.draining)
            .map(|r| Arc::strong_count(r) - 1)
            .sum()
    }

    /// Snapshot every slot for the control plane / CLI.
    pub fn snapshot(&self) -> Vec<VariantStatus> {
        self.slots
            .values()
            .map(|s| VariantStatus {
                variant: s.current.variant.clone(),
                generation: s.current.generation,
                store_sha256: s.current.store_sha256.clone(),
                alloc: s.current.alloc.clone(),
                ratio: s.current.ratio,
                active_sessions: Arc::strong_count(&s.current) - 1,
                draining: s
                    .draining
                    .iter()
                    .map(|r| (r.generation, Arc::strong_count(r) - 1))
                    .collect(),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowrank::synth::{tiny_manifest_json, tiny_store_tensors, SynthStyle, TinyDims};
    use crate::storage::write_store;

    fn dims() -> TinyDims {
        TinyDims { vocab: 61, d: 16, heads: 2, layers: 2, ff: 24 }
    }

    fn artifacts(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("dobi_registry_{tag}"));
        std::fs::create_dir_all(&dir).unwrap();
        write_store(&dir.join("w.dobiw"),
                    &tiny_store_tensors(dims(), 0, SynthStyle::DenseF32)).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            tiny_manifest_json(dims(), 0, &[("tiny/dense", "dense", 1.0, "w.dobiw")]),
        )
        .unwrap();
        dir
    }

    fn load(tag: &str) -> LoadedVariant {
        let m = Manifest::load(&artifacts(tag)).unwrap();
        load_release(&m, "tiny/dense").unwrap()
    }

    #[test]
    fn install_bumps_generation_and_drains_old_current() {
        let mut reg = VariantRegistry::default();
        assert_eq!(reg.install("tiny/dense", load("gen")), 1);
        // a "session" pins generation 1
        let session = reg.current("tiny/dense").unwrap();
        assert_eq!(session.generation, 1);
        // swap: new admissions see generation 2 immediately
        assert_eq!(reg.install("tiny/dense", load("gen")), 2);
        assert_eq!(reg.current("tiny/dense").unwrap().generation, 2);
        // the old release drains while the session still holds it
        assert_eq!(reg.draining_sessions(), 1);
        assert_eq!(reg.sweep(), 0, "a referenced release must not be freed");
        let snap = reg.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].generation, 2);
        assert_eq!(snap[0].draining, vec![(1, 1)]);
        // session finishes -> next sweep frees exactly that release
        drop(session);
        assert_eq!(reg.draining_sessions(), 0);
        assert_eq!(reg.sweep(), 1);
        assert!(reg.snapshot()[0].draining.is_empty());
    }

    #[test]
    fn two_swaps_stack_draining_generations() {
        let mut reg = VariantRegistry::default();
        reg.install("tiny/dense", load("stack"));
        let s1 = reg.current("tiny/dense").unwrap();
        reg.install("tiny/dense", load("stack"));
        let s2 = reg.current("tiny/dense").unwrap();
        reg.install("tiny/dense", load("stack"));
        assert_eq!(reg.current("tiny/dense").unwrap().generation, 3);
        assert_eq!(reg.draining_sessions(), 2);
        assert_eq!(reg.snapshot()[0].draining, vec![(1, 1), (2, 1)]);
        // generations free independently, in whatever order sessions end
        drop(s2);
        assert_eq!(reg.sweep(), 1);
        assert_eq!(reg.snapshot()[0].draining, vec![(1, 1)]);
        drop(s1);
        assert_eq!(reg.sweep(), 1);
        assert_eq!(reg.draining_sessions(), 0);
    }

    #[test]
    fn unreferenced_old_release_frees_on_first_sweep() {
        let mut reg = VariantRegistry::default();
        reg.install("tiny/dense", load("free"));
        reg.install("tiny/dense", load("free"));
        // nobody held generation 1: the first sweep reclaims it
        assert_eq!(reg.sweep(), 1);
        assert_eq!(reg.sweep(), 0);
    }

    #[test]
    fn resolve_draft_checks_shape_compatibility() {
        use crate::lowrank::synth::tiny_model;
        let mut reg = VariantRegistry::default();
        reg.install("tiny/dense", load("spec"));
        // a same-shape factorized variant is a valid draft
        reg.install("tiny/draft", LoadedVariant {
            model: tiny_model(dims(), 0, true),
            store_sha256: None,
            alloc: "waterfill".into(),
            ratio: 0.3,
        });
        // a differently-shaped model is not
        reg.install("tiny/other", LoadedVariant {
            model: tiny_model(TinyDims { vocab: 61, d: 16, heads: 2, layers: 3, ff: 24 },
                              0, false),
            store_sha256: None,
            alloc: "waterfill".into(),
            ratio: 1.0,
        });
        let target = reg.current("tiny/dense").unwrap();
        let ok = reg.resolve_draft("tiny/draft", &target).unwrap();
        assert_eq!(ok.variant, "tiny/draft");
        assert!(reg.resolve_draft("tiny/other", &target).is_err(),
                "layer-count mismatch must be refused");
        assert!(reg.resolve_draft("tiny/nope", &target).is_err(),
                "unknown draft must be refused");
        // a variant may draft for itself (the degenerate pair)
        assert!(reg.resolve_draft("tiny/dense", &target).is_ok());
    }

    #[test]
    fn load_release_reports_manifest_metadata() {
        let m = Manifest::load(&artifacts("meta")).unwrap();
        let l = load_release(&m, "tiny/dense").unwrap();
        assert_eq!(l.alloc, "waterfill");
        assert!(l.store_sha256.is_none(), "synth fixture has no provenance block");
        assert!(load_release(&m, "tiny/nope").is_err());
    }
}
