//! Per-request decode sessions: one [`DecodeSession`] owns one
//! preallocated [`KvCache`] and exposes the two incremental entry points
//! the scheduler drives — `prefill(tokens)` once, then `step(token)` per
//! generated token — each returning the last position's logits from
//! [`FactorizedModel::forward_kv`].
//!
//! The session is deliberately model-*borrowing*: the scheduler owns the
//! loaded models (one per variant, shared across sessions) and passes the
//! right one in, so a thousand sessions cost a thousand KV caches, not a
//! thousand weight copies.
//!
//! Sessions carry no instrumentation of their own: the scheduler times
//! each `prefill`/`step` call around the session and records the spans
//! into [`crate::trace`] keyed by [`DecodeSession::id`] — the `id` is
//! what ties a session's `prefill`/`step`/`spec_*` spans to its
//! `queue_wait` and `request` lifecycle spans in the exported trace.

use anyhow::Result;

use crate::lowrank::model::KvCache;
use crate::lowrank::FactorizedModel;

/// One client generation in flight: prompt consumed, `kv` holding every
/// attended position, plus budget accounting.
pub struct DecodeSession {
    pub id: u64,
    pub variant: String,
    kv: KvCache,
    n_prompt: usize,
    n_generated: usize,
}

impl DecodeSession {
    /// A fresh session for `variant`, its cache sized to `capacity`
    /// positions of `model`'s geometry.
    pub fn new(id: u64, variant: &str, model: &FactorizedModel, capacity: usize) -> DecodeSession {
        DecodeSession {
            id,
            variant: variant.to_string(),
            kv: model.new_kv_cache(capacity),
            n_prompt: 0,
            n_generated: 0,
        }
    }

    /// Consume the prompt (and image features for VLM variants) in one
    /// batched incremental forward; returns the next-token logits.
    pub fn prefill(&mut self, model: &FactorizedModel, tokens: &[i32],
                   image: Option<&[f32]>) -> Result<Vec<f32>> {
        anyhow::ensure!(self.kv.is_empty(), "session {} already prefilled", self.id);
        let logits = model.forward_kv(tokens, &mut self.kv, image)?;
        self.n_prompt = self.kv.len();
        Ok(logits)
    }

    /// Append one generated token and return the logits for the next.
    pub fn step(&mut self, model: &FactorizedModel, token: i32) -> Result<Vec<f32>> {
        anyhow::ensure!(!self.kv.is_empty(), "session {}: step before prefill", self.id);
        let logits = model.forward_kv(&[token], &mut self.kv, None)?;
        self.n_generated += 1;
        Ok(logits)
    }

    /// Fused step: advance every session by one token in a single batched
    /// trunk walk ([`FactorizedModel::forward_kv_multi`]) — each weight
    /// tile dequantizes once for the whole group instead of once per
    /// session.  `tokens[i]` goes to `sessions[i]`; all sessions must
    /// share `model`'s variant and be prefilled.  Bit-identical to
    /// calling [`Self::step`] on each session in turn; on `Err` no
    /// session has advanced, so the caller can fall back to serial steps.
    pub fn step_many(model: &FactorizedModel, sessions: &mut [&mut DecodeSession],
                     tokens: &[i32]) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(sessions.len() == tokens.len(),
                        "{} sessions for {} tokens", sessions.len(), tokens.len());
        for s in sessions.iter() {
            anyhow::ensure!(!s.kv.is_empty(), "session {}: step before prefill", s.id);
        }
        let mut kvs: Vec<&mut KvCache> = sessions.iter_mut().map(|s| &mut s.kv).collect();
        let logits = model.forward_kv_multi(tokens, &mut kvs)?;
        for s in sessions.iter_mut() {
            s.n_generated += 1;
        }
        Ok(logits)
    }

    /// Speculative-verify step: append `tokens` in one batched multi-row
    /// trunk walk ([`FactorizedModel::forward_kv_rows`]) and return the
    /// logits of **every** appended row, row-major (tokens.len() × vocab).
    /// Row `i` is bit-identical to what a serial [`Self::step`] after
    /// `tokens[..i]` would return — the speculative parity contract.
    /// Rows the verifier rejects are rolled back with
    /// [`Self::rollback_to`].
    pub fn verify_rows(&mut self, model: &FactorizedModel, tokens: &[i32]) -> Result<Vec<f32>> {
        anyhow::ensure!(!self.kv.is_empty(), "session {}: verify before prefill", self.id);
        let rows = model.forward_kv_rows(tokens, &mut self.kv)?;
        self.n_generated += tokens.len();
        Ok(rows)
    }

    /// Roll the cache back to `positions` attended rows (speculative
    /// rejection), keeping the generated-token accounting consistent.
    /// `positions` may not cut into the prompt.
    pub fn rollback_to(&mut self, positions: usize) {
        assert!(positions >= self.n_prompt,
                "session {}: rollback_to({positions}) would cut into the {}-row prompt",
                self.id, self.n_prompt);
        self.kv.truncate_to(positions);
        self.n_generated = positions - self.n_prompt;
    }

    /// Attended positions so far (prefix + prompt + generated).
    pub fn positions(&self) -> usize {
        self.kv.len()
    }

    /// Prompt positions consumed at prefill (incl. any image prefix).
    pub fn prompt_len(&self) -> usize {
        self.n_prompt
    }

    /// Tokens appended via [`Self::step`].
    pub fn generated(&self) -> usize {
        self.n_generated
    }

    /// Steps still admissible before the KV cache is full.
    pub fn remaining(&self) -> usize {
        self.kv.remaining()
    }

    /// Host bytes this session's cache currently pins.
    pub fn kv_bytes(&self) -> usize {
        self.kv.resident_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowrank::synth::{tiny_model, TinyDims};
    use crate::mathx::argmax;

    fn model() -> FactorizedModel {
        tiny_model(TinyDims { vocab: 61, d: 16, heads: 2, layers: 2, ff: 24 }, 0, false)
    }

    #[test]
    fn session_lifecycle_and_accounting() {
        let m = model();
        let mut s = DecodeSession::new(7, "tiny/x", &m, 16);
        assert!(s.step(&m, 1).is_err(), "step before prefill must fail");
        let prompt: Vec<i32> = (0..5).collect();
        let logits = s.prefill(&m, &prompt, None).unwrap();
        assert_eq!(logits.len(), m.vocab);
        assert_eq!((s.prompt_len(), s.positions(), s.generated()), (5, 5, 0));
        assert!(s.prefill(&m, &prompt, None).is_err(), "double prefill must fail");
        let next = argmax(&logits) as i32;
        let logits = s.step(&m, next).unwrap();
        assert_eq!(logits.len(), m.vocab);
        assert_eq!((s.positions(), s.generated(), s.remaining()), (6, 1, 10));
        assert!(s.kv_bytes() > 0);
    }

    #[test]
    fn step_many_matches_serial_steps() {
        let m = model();
        let mut a1 = DecodeSession::new(1, "tiny/x", &m, 16);
        let mut a2 = DecodeSession::new(2, "tiny/x", &m, 16);
        let mut b1 = DecodeSession::new(3, "tiny/x", &m, 16);
        let mut b2 = DecodeSession::new(4, "tiny/x", &m, 16);
        let l1 = a1.prefill(&m, &[1, 2, 3], None).unwrap();
        let l2 = a2.prefill(&m, &[4, 5], None).unwrap();
        b1.prefill(&m, &[1, 2, 3], None).unwrap();
        b2.prefill(&m, &[4, 5], None).unwrap();
        let t1 = argmax(&l1) as i32;
        let t2 = argmax(&l2) as i32;
        let s1 = a1.step(&m, t1).unwrap();
        let s2 = a2.step(&m, t2).unwrap();
        let fused = DecodeSession::step_many(&m, &mut [&mut b1, &mut b2], &[t1, t2]).unwrap();
        assert_eq!(fused, vec![s1, s2], "fused step must be bit-identical to serial");
        assert_eq!((b1.generated(), b2.generated()), (1, 1));
        assert_eq!(b1.positions(), a1.positions());
        // an un-prefilled member fails the whole call without advancing anyone
        let mut c = DecodeSession::new(5, "tiny/x", &m, 16);
        assert!(DecodeSession::step_many(&m, &mut [&mut b1, &mut c], &[t1, t2]).is_err());
        assert_eq!(b1.generated(), 1);
    }

    #[test]
    fn session_runs_out_of_capacity_cleanly() {
        let m = model();
        let mut s = DecodeSession::new(1, "tiny/x", &m, 6);
        s.prefill(&m, &[1, 2, 3, 4], None).unwrap();
        s.step(&m, 5).unwrap();
        s.step(&m, 6).unwrap();
        assert_eq!(s.remaining(), 0);
        assert!(s.step(&m, 7).is_err(), "stepping past capacity must fail");
        // the failed step must not corrupt accounting
        assert_eq!((s.positions(), s.generated()), (6, 2));
    }
}
