//! Per-request decode sessions: one [`DecodeSession`] owns one
//! preallocated [`KvCache`] and exposes the two incremental entry points
//! the scheduler drives — `prefill(tokens)` once, then `step(token)` per
//! generated token — each returning the last position's logits from
//! [`FactorizedModel::forward_kv`].
//!
//! The session is deliberately model-*borrowing*: the scheduler owns the
//! loaded models (one per variant, shared across sessions) and passes the
//! right one in, so a thousand sessions cost a thousand KV caches, not a
//! thousand weight copies.

use anyhow::Result;

use crate::lowrank::model::KvCache;
use crate::lowrank::FactorizedModel;

/// One client generation in flight: prompt consumed, `kv` holding every
/// attended position, plus budget accounting.
pub struct DecodeSession {
    pub id: u64,
    pub variant: String,
    kv: KvCache,
    n_prompt: usize,
    n_generated: usize,
}

impl DecodeSession {
    /// A fresh session for `variant`, its cache sized to `capacity`
    /// positions of `model`'s geometry.
    pub fn new(id: u64, variant: &str, model: &FactorizedModel, capacity: usize) -> DecodeSession {
        DecodeSession {
            id,
            variant: variant.to_string(),
            kv: model.new_kv_cache(capacity),
            n_prompt: 0,
            n_generated: 0,
        }
    }

    /// Consume the prompt (and image features for VLM variants) in one
    /// batched incremental forward; returns the next-token logits.
    pub fn prefill(&mut self, model: &FactorizedModel, tokens: &[i32],
                   image: Option<&[f32]>) -> Result<Vec<f32>> {
        anyhow::ensure!(self.kv.is_empty(), "session {} already prefilled", self.id);
        let logits = model.forward_kv(tokens, &mut self.kv, image)?;
        self.n_prompt = self.kv.len();
        Ok(logits)
    }

    /// Append one generated token and return the logits for the next.
    pub fn step(&mut self, model: &FactorizedModel, token: i32) -> Result<Vec<f32>> {
        anyhow::ensure!(!self.kv.is_empty(), "session {}: step before prefill", self.id);
        let logits = model.forward_kv(&[token], &mut self.kv, None)?;
        self.n_generated += 1;
        Ok(logits)
    }

    /// Attended positions so far (prefix + prompt + generated).
    pub fn positions(&self) -> usize {
        self.kv.len()
    }

    /// Prompt positions consumed at prefill (incl. any image prefix).
    pub fn prompt_len(&self) -> usize {
        self.n_prompt
    }

    /// Tokens appended via [`Self::step`].
    pub fn generated(&self) -> usize {
        self.n_generated
    }

    /// Steps still admissible before the KV cache is full.
    pub fn remaining(&self) -> usize {
        self.kv.remaining()
    }

    /// Host bytes this session's cache currently pins.
    pub fn kv_bytes(&self) -> usize {
        self.kv.resident_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowrank::synth::{tiny_model, TinyDims};
    use crate::mathx::argmax;

    fn model() -> FactorizedModel {
        tiny_model(TinyDims { vocab: 61, d: 16, heads: 2, layers: 2, ff: 24 }, 0, false)
    }

    #[test]
    fn session_lifecycle_and_accounting() {
        let m = model();
        let mut s = DecodeSession::new(7, "tiny/x", &m, 16);
        assert!(s.step(&m, 1).is_err(), "step before prefill must fail");
        let prompt: Vec<i32> = (0..5).collect();
        let logits = s.prefill(&m, &prompt, None).unwrap();
        assert_eq!(logits.len(), m.vocab);
        assert_eq!((s.prompt_len(), s.positions(), s.generated()), (5, 5, 0));
        assert!(s.prefill(&m, &prompt, None).is_err(), "double prefill must fail");
        let next = argmax(&logits) as i32;
        let logits = s.step(&m, next).unwrap();
        assert_eq!(logits.len(), m.vocab);
        assert_eq!((s.positions(), s.generated(), s.remaining()), (6, 1, 10));
        assert!(s.kv_bytes() > 0);
    }

    #[test]
    fn session_runs_out_of_capacity_cleanly() {
        let m = model();
        let mut s = DecodeSession::new(1, "tiny/x", &m, 6);
        s.prefill(&m, &[1, 2, 3, 4], None).unwrap();
        s.step(&m, 5).unwrap();
        s.step(&m, 6).unwrap();
        assert_eq!(s.remaining(), 0);
        assert!(s.step(&m, 7).is_err(), "stepping past capacity must fail");
        // the failed step must not corrupt accounting
        assert_eq!((s.positions(), s.generated()), (6, 2));
    }
}
