//! Minimal SHA-256 (FIPS 180-4) — the content hash behind `.dobiw`
//! provenance pinning.  No dependency, no hardware paths: a straight
//! portable implementation, fast enough for artifact-sized inputs (the
//! stores this repo hashes are megabytes, hashed once per load/release).
//!
//! Why SHA-256 on top of the per-tensor CRC32 already in the container
//! format: CRC catches *accidental* bit rot inside one payload, but says
//! nothing about a store that was *replaced wholesale* (re-compressed at
//! the same path, foreign artifact under a known name).  The manifest
//! pins the digest of the exact bytes `dobi compress` wrote, so a load
//! can refuse anything that is valid-but-different.

/// Round constants: fractional parts of the cube roots of the first 64
/// primes (FIPS 180-4 §4.2.2).
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1,
    0x923f82a4, 0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3,
    0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786,
    0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147,
    0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13,
    0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
    0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a,
    0x5b9cca4f, 0x682e6ff3, 0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208,
    0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Initial hash values: fractional parts of the square roots of the
/// first 8 primes (FIPS 180-4 §5.3.3).
const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a,
    0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

fn compress_block(state: &mut [u32; 8], block: &[u8]) {
    debug_assert_eq!(block.len(), 64);
    let mut w = [0u32; 64];
    for (i, c) in block.chunks_exact(4).enumerate() {
        w[i] = u32::from_be_bytes(c.try_into().unwrap());
    }
    for i in 16..64 {
        let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
        let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
        w[i] = w[i - 16]
            .wrapping_add(s0)
            .wrapping_add(w[i - 7])
            .wrapping_add(s1);
    }
    let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = *state;
    for i in 0..64 {
        let big_s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
        let ch = (e & f) ^ (!e & g);
        let t1 = h
            .wrapping_add(big_s1)
            .wrapping_add(ch)
            .wrapping_add(K[i])
            .wrapping_add(w[i]);
        let big_s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
        let maj = (a & b) ^ (a & c) ^ (b & c);
        let t2 = big_s0.wrapping_add(maj);
        h = g;
        g = f;
        f = e;
        e = d.wrapping_add(t1);
        d = c;
        c = b;
        b = a;
        a = t1.wrapping_add(t2);
    }
    for (s, v) in state.iter_mut().zip([a, b, c, d, e, f, g, h]) {
        *s = s.wrapping_add(v);
    }
}

/// SHA-256 digest of `data`.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut state = H0;
    let mut chunks = data.chunks_exact(64);
    for block in &mut chunks {
        compress_block(&mut state, block);
    }
    // Padding: 0x80, zeros to 56 mod 64, then the bit length big-endian.
    let rem = chunks.remainder();
    let mut tail = [0u8; 128];
    tail[..rem.len()].copy_from_slice(rem);
    tail[rem.len()] = 0x80;
    let tail_len = if rem.len() < 56 { 64 } else { 128 };
    let bits = (data.len() as u64).wrapping_mul(8);
    tail[tail_len - 8..tail_len].copy_from_slice(&bits.to_be_bytes());
    for block in tail[..tail_len].chunks_exact(64) {
        compress_block(&mut state, block);
    }
    let mut out = [0u8; 32];
    for (o, s) in out.chunks_exact_mut(4).zip(state) {
        o.copy_from_slice(&s.to_be_bytes());
    }
    out
}

/// Lowercase-hex SHA-256 digest — the form manifests pin and humans diff.
pub fn sha256_hex(data: &[u8]) -> String {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let d = sha256(data);
    let mut s = String::with_capacity(64);
    for b in d {
        s.push(HEX[(b >> 4) as usize] as char);
        s.push(HEX[(b & 0xf) as usize] as char);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    // FIPS 180-4 / RFC 6234 test vectors (verifiable against any
    // reference implementation, e.g. `hashlib.sha256`).
    #[test]
    fn empty_input() {
        assert_eq!(sha256_hex(b""),
                   "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855");
    }

    #[test]
    fn abc() {
        assert_eq!(sha256_hex(b"abc"),
                   "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad");
    }

    #[test]
    fn two_block_message() {
        assert_eq!(sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
                   "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1");
    }

    #[test]
    fn length_padding_boundaries() {
        // 55/56/63/64/65 bytes straddle the one-vs-two padding blocks;
        // digests verified against hashlib.sha256 on b'a' * n
        let cases = [
            (55usize, "9f4390f8d30c2dd92ec9f095b65e2b9ae9b0a925a5258e241c9f1e910f734318"),
            (56, "b35439a4ac6f0948b6d6f9e3c6af0f5f590ce20f1bde7090ef7970686ec6738a"),
            (63, "7d3e74a05d7db15bce4ad9ec0658ea98e3f06eeecf16b4c6fff2da457ddc2f34"),
            (64, "ffe054fe7ae0cb6dc65c3af9b61d5209f439851db43d0ba5997337df154668eb"),
            (65, "635361c48bb9eab14198e76ea8ab7f1a41685d6ad62aa9146d301d4f17eb0ae0"),
        ];
        for (n, want) in cases {
            assert_eq!(sha256_hex(&vec![b'a'; n]), want, "n={n}");
        }
    }

    #[test]
    fn million_a() {
        assert_eq!(sha256_hex(&vec![b'a'; 1_000_000]),
                   "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0");
    }
}
