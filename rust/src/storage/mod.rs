//! `.dobiw` weight-container reader + storage accounting.
//!
//! Format (little-endian) — mirror of `python/compile/dobiw.py`:
//! ```text
//! magic "DOBIW1" | u32 n_tensors | per tensor:
//!   u16 name_len | name | u8 dtype | u8 ndim | u32*ndim shape |
//!   u64 payload_len | payload | u32 crc32(payload)
//! ```
//! dtype: 0 = f32, 1 = f16, 2 = i8, 3 = i32.
//!
//! Remapped Dobi factors arrive as `<name>.q8` + `<name>.scales`
//! (broadcast-shaped); [`Store::tensor_f32`] reassembles the fp32 tensor
//! exactly as `aot._arrays_from_store` does on the python side.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::corpusio::crc32;
use crate::quant::{dequantize_i8, f16_slice_to_f32, f32_to_f16};

pub mod hash;

pub const MAGIC: &[u8; 6] = b"DOBIW1";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    F16,
    I8,
    I32,
}

impl Dtype {
    fn from_code(c: u8) -> Result<Dtype> {
        Ok(match c {
            0 => Dtype::F32,
            1 => Dtype::F16,
            2 => Dtype::I8,
            3 => Dtype::I32,
            _ => bail!("unknown dtype code {c}"),
        })
    }

    pub fn elem_bytes(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::F16 => 2,
            Dtype::I8 => 1,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn n_elems(&self) -> usize {
        self.shape.iter().product()
    }

    /// Decode to f32 (f16 upconverted; i8 returned as raw codes cast).
    pub fn to_f32(&self) -> Vec<f32> {
        match self.dtype {
            Dtype::F32 => self
                .data
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect(),
            Dtype::F16 => {
                let halves: Vec<u16> = self
                    .data
                    .chunks_exact(2)
                    .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                f16_slice_to_f32(&halves)
            }
            Dtype::I8 => self.data.iter().map(|&b| b as i8 as f32).collect(),
            Dtype::I32 => self
                .data
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()) as f32)
                .collect(),
        }
    }

    pub fn as_i8(&self) -> Vec<i8> {
        assert_eq!(self.dtype, Dtype::I8);
        self.data.iter().map(|&b| b as i8).collect()
    }

    /// SHA-256 of the raw payload bytes — the per-tensor section hash
    /// provenance manifests pin (and loads verify).
    pub fn payload_sha256(&self) -> String {
        hash::sha256_hex(&self.data)
    }
}

#[derive(Debug, Default)]
pub struct Store {
    pub tensors: BTreeMap<String, Tensor>,
    pub file_bytes: usize,
    /// SHA-256 (hex) of the exact container bytes this store parsed from —
    /// compared against the manifest's provenance pin at load time.
    pub content_sha256: String,
}

impl Store {
    pub fn open(path: &Path) -> Result<Store> {
        let raw = std::fs::read(path).map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&raw).map_err(|e| anyhow!("{}: {e}", path.display()))
    }

    pub fn parse(raw: &[u8]) -> Result<Store> {
        if raw.len() < 10 || &raw[..6] != MAGIC {
            bail!("bad dobiw magic");
        }
        let n = u32::from_le_bytes(raw[6..10].try_into().unwrap()) as usize;
        let mut i = 10usize;
        let mut tensors = BTreeMap::new();
        let take = |i: &mut usize, len: usize| -> Result<&[u8]> {
            if *i + len > raw.len() {
                bail!("truncated dobiw at byte {i}");
            }
            let s = &raw[*i..*i + len];
            *i += len;
            Ok(s)
        };
        for _ in 0..n {
            let nl = u16::from_le_bytes(take(&mut i, 2)?.try_into().unwrap()) as usize;
            let name = String::from_utf8(take(&mut i, nl)?.to_vec())?;
            let hdr = take(&mut i, 2)?;
            let dtype = Dtype::from_code(hdr[0])?;
            let ndim = hdr[1] as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(u32::from_le_bytes(take(&mut i, 4)?.try_into().unwrap()) as usize);
            }
            let plen = u64::from_le_bytes(take(&mut i, 8)?.try_into().unwrap()) as usize;
            let data = take(&mut i, plen)?.to_vec();
            let want = u32::from_le_bytes(take(&mut i, 4)?.try_into().unwrap());
            if crc32(&data) != want {
                bail!("crc mismatch for tensor `{name}`");
            }
            let expect = shape.iter().product::<usize>() * dtype.elem_bytes();
            if expect != data.len() {
                bail!("tensor `{name}` payload {} != shape-implied {expect}", data.len());
            }
            tensors.insert(name.clone(), Tensor { name, dtype, shape, data });
        }
        Ok(Store {
            tensors,
            file_bytes: raw.len(),
            content_sha256: hash::sha256_hex(raw),
        })
    }

    /// Reassemble the named HLO parameter as f32 row-major + its shape.
    /// Plain tensors pass through; `name.q8`+`name.scales` dequantize.
    pub fn tensor_f32(&self, name: &str) -> Result<(Vec<f32>, Vec<usize>)> {
        if let Some(t) = self.tensors.get(name) {
            return Ok((t.to_f32(), t.shape.clone()));
        }
        let q = self
            .tensors
            .get(&format!("{name}.q8"))
            .ok_or_else(|| anyhow!("tensor `{name}` not in store (plain or quantized)"))?;
        let s = self
            .tensors
            .get(&format!("{name}.scales"))
            .ok_or_else(|| anyhow!("tensor `{name}.scales` missing"))?;
        anyhow::ensure!(q.shape.len() == 2 && s.shape.len() == 2,
                        "quantized tensors must be 2-D");
        let (rows, cols) = (q.shape[0], q.shape[1]);
        let scales = s.to_f32();
        let out = dequantize_i8(&q.as_i8(), rows, cols, &scales, (s.shape[0], s.shape[1]));
        Ok((out, q.shape.clone()))
    }

    /// True bytes this parameter set occupies on disk per tensor payloads
    /// (scales included) — the deployment memory the tables report.
    pub fn payload_bytes(&self) -> usize {
        self.tensors.values().map(|t| t.data.len()).sum()
    }
}

/// Encode tensors into the `.dobiw` container layout.  Deterministic for
/// a given tensor sequence — the property that makes the provenance pin
/// (`hash::sha256_hex` of these bytes) reproducible.
pub fn encode_store(tensors: &[Tensor]) -> Vec<u8> {
    let mut out: Vec<u8> = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for t in tensors {
        let nb = t.name.as_bytes();
        out.extend_from_slice(&(nb.len() as u16).to_le_bytes());
        out.extend_from_slice(nb);
        let code = match t.dtype {
            Dtype::F32 => 0u8,
            Dtype::F16 => 1,
            Dtype::I8 => 2,
            Dtype::I32 => 3,
        };
        out.push(code);
        out.push(t.shape.len() as u8);
        for &d in &t.shape {
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        out.extend_from_slice(&(t.data.len() as u64).to_le_bytes());
        out.extend_from_slice(&t.data);
        out.extend_from_slice(&crc32(&t.data).to_le_bytes());
    }
    out
}

/// Writer (round-trip tests + rust-side artifact generation).
pub fn write_store(path: &Path, tensors: &[Tensor]) -> Result<()> {
    std::fs::write(path, encode_store(tensors))?;
    Ok(())
}

pub fn f32_tensor(name: &str, shape: Vec<usize>, vals: &[f32]) -> Tensor {
    assert_eq!(shape.iter().product::<usize>(), vals.len());
    Tensor {
        name: name.to_string(),
        dtype: Dtype::F32,
        shape,
        data: vals.iter().flat_map(|v| v.to_le_bytes()).collect(),
    }
}

/// Encode f32 values as an f16 tensor (round-to-nearest-even).
pub fn f16_tensor(name: &str, shape: Vec<usize>, vals: &[f32]) -> Tensor {
    assert_eq!(shape.iter().product::<usize>(), vals.len());
    Tensor {
        name: name.to_string(),
        dtype: Dtype::F16,
        shape,
        data: vals.iter().flat_map(|&v| f32_to_f16(v).to_le_bytes()).collect(),
    }
}

pub fn i8_tensor(name: &str, shape: Vec<usize>, codes: &[i8]) -> Tensor {
    assert_eq!(shape.iter().product::<usize>(), codes.len());
    Tensor {
        name: name.to_string(),
        dtype: Dtype::I8,
        shape,
        data: codes.iter().map(|&c| c as u8).collect(),
    }
}

pub fn i32_tensor(name: &str, shape: Vec<usize>, vals: &[i32]) -> Tensor {
    assert_eq!(shape.iter().product::<usize>(), vals.len());
    Tensor {
        name: name.to_string(),
        dtype: Dtype::I32,
        shape,
        data: vals.iter().flat_map(|v| v.to_le_bytes()).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("dobi_storage_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_f32() {
        let p = tmp("a.dobiw");
        let t = f32_tensor("x", vec![2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        write_store(&p, &[t]).unwrap();
        let s = Store::open(&p).unwrap();
        let (v, shape) = s.tensor_f32("x").unwrap();
        assert_eq!(shape, vec![2, 3]);
        assert_eq!(v, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn dequantizes_q8_pairs() {
        let p = tmp("b.dobiw");
        let q = Tensor {
            name: "w.q8".into(),
            dtype: Dtype::I8,
            shape: vec![2, 2],
            data: vec![10i8 as u8, 20i8 as u8, (-10i8) as u8, 5u8],
        };
        let s = f32_tensor("w.scales", vec![1, 2], &[0.1, 0.5]);
        write_store(&p, &[q, s]).unwrap();
        let store = Store::open(&p).unwrap();
        let (v, shape) = store.tensor_f32("w").unwrap();
        assert_eq!(shape, vec![2, 2]);
        let want = [1.0f32, 10.0, -1.0, 2.5];
        for (a, b) in v.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn crc_corruption_detected() {
        let p = tmp("c.dobiw");
        write_store(&p, &[f32_tensor("x", vec![4], &[1.0, 2.0, 3.0, 4.0])]).unwrap();
        let mut raw = std::fs::read(&p).unwrap();
        let n = raw.len();
        raw[n - 8] ^= 0x1;
        std::fs::write(&p, raw).unwrap();
        assert!(Store::open(&p).is_err());
    }

    #[test]
    fn shape_payload_mismatch_detected() {
        let t = Tensor { name: "x".into(), dtype: Dtype::F32, shape: vec![3], data: vec![0; 8] };
        let p = tmp("d.dobiw");
        write_store(&p, &[t]).unwrap();
        assert!(Store::open(&p).is_err());
    }

    #[test]
    fn missing_tensor_error() {
        let p = tmp("e.dobiw");
        write_store(&p, &[f32_tensor("x", vec![1], &[0.0])]).unwrap();
        let s = Store::open(&p).unwrap();
        assert!(s.tensor_f32("y").is_err());
    }

    #[test]
    fn f16_upconversion() {
        let p = tmp("f.dobiw");
        let halves: Vec<u8> = [0x3C00u16, 0xC000].iter().flat_map(|h| h.to_le_bytes()).collect();
        let t = Tensor { name: "h".into(), dtype: Dtype::F16, shape: vec![2], data: halves };
        write_store(&p, &[t]).unwrap();
        let s = Store::open(&p).unwrap();
        let (v, _) = s.tensor_f32("h").unwrap();
        assert_eq!(v, vec![1.0, -2.0]);
    }

    #[test]
    fn roundtrip_all_dtypes() {
        // Writer-side coverage: every dtype survives write -> read with
        // exact payload bytes, shapes, and decoded values.
        let p = tmp("all_dtypes.dobiw");
        let tensors = vec![
            f32_tensor("a", vec![2, 2], &[1.5, -2.5, 0.0, 3.25]),
            f16_tensor("b", vec![3], &[1.0, -2.0, 0.5]),
            i8_tensor("c", vec![2, 2], &[1, -1, 127, -127]),
            i32_tensor("d", vec![2], &[-7, 1_000_000]),
        ];
        write_store(&p, &tensors).unwrap();
        let s = Store::open(&p).unwrap();
        assert_eq!(s.tensors.len(), 4);
        for t in &tensors {
            let got = &s.tensors[&t.name];
            assert_eq!(got.dtype, t.dtype, "{}: dtype", t.name);
            assert_eq!(got.shape, t.shape, "{}: shape", t.name);
            assert_eq!(got.data, t.data, "{}: payload", t.name);
        }
        assert_eq!(s.tensors["a"].to_f32(), vec![1.5, -2.5, 0.0, 3.25]);
        assert_eq!(s.tensors["b"].to_f32(), vec![1.0, -2.0, 0.5]);
        assert_eq!(s.tensors["c"].as_i8(), vec![1, -1, 127, -127]);
        assert_eq!(s.tensors["d"].to_f32(), vec![-7.0, 1_000_000.0]);
    }

    #[test]
    fn truncated_file_rejected_at_every_cut() {
        let p = tmp("trunc.dobiw");
        write_store(&p, &[
            f32_tensor("x", vec![3], &[1.0, 2.0, 3.0]),
            i8_tensor("y", vec![2], &[4, -4]),
        ])
        .unwrap();
        let raw = std::fs::read(&p).unwrap();
        // any strict prefix must fail to parse (header, name, payload, crc)
        for cut in [raw.len() - 1, raw.len() - 4, raw.len() / 2, 9, 6, 1] {
            assert!(Store::parse(&raw[..cut]).is_err(), "prefix of {cut} bytes accepted");
        }
        assert!(Store::parse(&raw).is_ok());
    }

    #[test]
    fn bad_crc_rejected_in_any_tensor() {
        let p = tmp("crc2.dobiw");
        write_store(&p, &[
            f32_tensor("x", vec![2], &[1.0, 2.0]),
            f32_tensor("y", vec![2], &[3.0, 4.0]),
        ])
        .unwrap();
        let good = std::fs::read(&p).unwrap();
        // flip one payload byte of each tensor in turn; the reader must
        // reject both (not just the first)
        let mut seen_rejects = 0;
        for i in 10..good.len() {
            let mut raw = good.clone();
            raw[i] ^= 0x40;
            if Store::parse(&raw).is_err() {
                seen_rejects += 1;
            }
        }
        // every byte after the header matters (name, dtype, shape, payload,
        // or crc corruption all fail): a large majority must reject
        assert!(seen_rejects > (good.len() - 10) * 3 / 4,
                "only {seen_rejects} corruptions detected");
    }

    #[test]
    fn writer_is_deterministic() {
        let t = || vec![f32_tensor("x", vec![2], &[1.0, 2.0]), i8_tensor("q", vec![1], &[5])];
        let (p1, p2) = (tmp("det1.dobiw"), tmp("det2.dobiw"));
        write_store(&p1, &t()).unwrap();
        write_store(&p2, &t()).unwrap();
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
    }

    #[test]
    fn content_hash_tracks_exact_bytes() {
        let tensors = vec![f32_tensor("x", vec![2], &[1.0, 2.0]), i8_tensor("q", vec![1], &[5])];
        let raw = encode_store(&tensors);
        let s = Store::parse(&raw).unwrap();
        // the store's self-reported hash IS the hash of the encoded bytes
        assert_eq!(s.content_sha256, hash::sha256_hex(&raw));
        assert_eq!(s.content_sha256.len(), 64);
        // per-tensor section hashes cover the payload bytes only
        assert_eq!(s.tensors["x"].payload_sha256(),
                   hash::sha256_hex(&1.0f32.to_le_bytes().iter().copied()
                       .chain(2.0f32.to_le_bytes())
                       .collect::<Vec<u8>>()));
        // a different (valid) store hashes differently — the case CRC32
        // cannot catch: wholesale replacement with another good container
        let other = encode_store(&[f32_tensor("x", vec![2], &[1.0, 2.5])]);
        assert_ne!(Store::parse(&other).unwrap().content_sha256, s.content_sha256);
        // write_store writes exactly encode_store's bytes
        let p = tmp("hash.dobiw");
        write_store(&p, &tensors).unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), raw);
    }

    #[test]
    fn payload_bytes_accounting() {
        let p = tmp("g.dobiw");
        write_store(&p, &[f32_tensor("x", vec![10], &[0.0; 10])]).unwrap();
        let s = Store::open(&p).unwrap();
        assert_eq!(s.payload_bytes(), 40);
        assert!(s.file_bytes > 40);
    }
}
