//! Capacity-constrained device simulator — the Table 10 substrate.
//!
//! The paper's 12.4x Titan-Xp speedup has one mechanism: the dense fp16
//! LLaMA-7B (~14.8 GB) does not fit 12 GB, so every forward pages weights
//! over PCIe, while the Dobi-compressed model is fully resident.  We model
//! exactly that: a device with `capacity` bytes and `bandwidth` host->device
//! bytes/s; any non-resident weight bytes are re-streamed once per forward
//! pass (weights are consumed layer by layer, so an LRU of size `capacity`
//! misses every non-resident byte every pass).  Compute time comes from
//! *measured* executions on the real runtime; only the transfer is modeled.
//!
//! Scaled device presets mirror the paper's hardware grid at nano scale.

#[derive(Debug, Clone)]
pub struct DeviceModel {
    pub name: String,
    /// Usable weight memory (after framework workspace), bytes.
    pub capacity: usize,
    /// Effective host->device bandwidth, bytes/s.
    pub bandwidth: f64,
}

impl DeviceModel {
    /// "titan-nano": fits the compressed nano models (<= 3 MB remapped)
    /// but not the dense fp16 one (3.64 MB) — the paper's 12 GB vs
    /// 14.8 GB situation scaled to our substrate.  Host-link bandwidth is
    /// scaled so paging dominates the pass time the way PCIe paging of
    /// 2.8 GB dominated the paper's Titan Xp runs (their 2.09 tok/s).
    pub fn titan_nano() -> DeviceModel {
        DeviceModel { name: "titan-nano-3.2MB".into(), capacity: 3_200_000, bandwidth: 4e6 }
    }

    /// "a100-nano": everything fits; speedups come from FLOPs alone.
    pub fn a100_nano() -> DeviceModel {
        DeviceModel { name: "a100-nano-64MB".into(), capacity: 64 << 20, bandwidth: 2e9 }
    }

    pub fn fits(&self, model_bytes: usize) -> bool {
        model_bytes <= self.capacity
    }

    /// Bytes that must be streamed from host per forward pass.
    pub fn paged_bytes_per_pass(&self, model_bytes: usize) -> usize {
        model_bytes.saturating_sub(self.capacity)
    }

    /// Seconds added to one forward pass by paging.
    pub fn paging_seconds(&self, model_bytes: usize) -> f64 {
        self.paged_bytes_per_pass(model_bytes) as f64 / self.bandwidth
    }

    /// End-to-end tokens/s on this device given the measured on-device
    /// compute seconds per pass and tokens produced per pass.
    pub fn tokens_per_s(&self, model_bytes: usize, compute_s_per_pass: f64,
                        tokens_per_pass: usize) -> SimResult {
        let paging = self.paging_seconds(model_bytes);
        let total = compute_s_per_pass + paging;
        SimResult {
            resident: self.fits(model_bytes),
            paged_bytes: self.paged_bytes_per_pass(model_bytes),
            compute_s: compute_s_per_pass,
            paging_s: paging,
            tokens_per_s: tokens_per_pass as f64 / total,
        }
    }
}

#[derive(Debug, Clone)]
pub struct SimResult {
    pub resident: bool,
    pub paged_bytes: usize,
    pub compute_s: f64,
    pub paging_s: f64,
    pub tokens_per_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resident_model_no_paging() {
        let d = DeviceModel::titan_nano();
        let r = d.tokens_per_s(1 << 20, 0.01, 32);
        assert!(r.resident);
        assert_eq!(r.paged_bytes, 0);
        assert!((r.tokens_per_s - 3200.0).abs() < 1e-6);
    }

    #[test]
    fn oversized_model_pays_bandwidth() {
        let d = DeviceModel { name: "t".into(), capacity: 1000, bandwidth: 1000.0 };
        let r = d.tokens_per_s(3000, 0.0, 10);
        assert!(!r.resident);
        assert_eq!(r.paged_bytes, 2000);
        assert!((r.paging_s - 2.0).abs() < 1e-9);
        assert!((r.tokens_per_s - 5.0).abs() < 1e-9);
    }

    #[test]
    fn speedup_shape_matches_paper() {
        // dense doesn't fit, compressed does -> order-of-magnitude speedup
        // even when the compressed model computes at the same rate.
        let d = DeviceModel::titan_nano();
        let dense = d.tokens_per_s(3_640_000, 0.013, 256); // fp16 dense > cap
        let dobi = d.tokens_per_s(2_200_000, 0.013, 256);  // remapped fits
        assert!(!dense.resident && dobi.resident);
        let speedup = dobi.tokens_per_s / dense.tokens_per_s;
        assert!(speedup > 5.0, "speedup {speedup}");
    }

    #[test]
    fn monotone_in_model_size() {
        let d = DeviceModel::titan_nano();
        let mut last = f64::INFINITY;
        for kb in [2_000usize, 3_000, 3_500, 5_000, 9_000] {
            let r = d.tokens_per_s(kb * 1000, 0.002, 32);
            assert!(r.tokens_per_s <= last + 1e-9);
            last = r.tokens_per_s;
        }
    }
}
