//! Multimodal serving demo: VQA through the compressed vision-language
//! model (paper §4.4 / Tables 11-12) — loads the vlm-nano variants,
//! answers image questions, and reports accuracy + speed per ratio.
//!
//! ```bash
//! make artifacts && cargo run --release --example vlm_assistant
//! ```

use anyhow::Result;
use dobi::bench::{artifacts_dir, bench_for, Table};
use dobi::config::Manifest;
use dobi::corpusio;
use dobi::evalx;
use dobi::runtime::Runtime;

fn main() -> Result<()> {
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let Some(vqa_file) = manifest.vqa_file.clone() else {
        println!("no VQA artifacts in this build profile");
        return Ok(());
    };
    let (_, samples) = corpusio::read_vqa(&manifest.path(&vqa_file))?;
    let (b, s) = (manifest.eval_batch, manifest.eval_seq);
    let rt = Runtime::new()?;

    let mut table = Table::new("VLM assistant — accuracy and speed per compression ratio",
                               &["variant", "ratio", "MB", "VQA acc", "tok/s"]);
    for id in ["vlm-nano/dense", "vlm-nano/dobi_80", "vlm-nano/dobi_60", "vlm-nano/dobi_40"] {
        let Ok(v) = manifest.variant(id) else { continue };
        if v.hlo_for(b, s).is_none() {
            continue;
        }
        let model = rt.load_variant(&manifest, id, Some(&[(b, s)]))?;
        let acc = evalx::run_vqa(&model, &samples, b, s, 40)?;
        let tokens = vec![32i32; b * s];
        let image = vec![0.1f32; b * model.img_dim];
        let speed = bench_for(id, 0.3, 3, || {
            model.forward(b, s, &tokens, Some(&image)).unwrap();
        });
        table.row(vec![
            id.to_string(),
            format!("{:.1}", v.ratio),
            format!("{:.2}", v.bytes as f64 / 1e6),
            format!("{:.3}", acc.accuracy),
            format!("{:.0}", speed.throughput((b * s) as f64)),
        ]);
    }
    table.print();

    // One concrete interaction for flavor.
    if let Ok(model) = rt.load_variant(&manifest, "vlm-nano/dobi_60", Some(&[(b, s)])) {
        if let Some(sample) = samples.first() {
            let mut best = (f32::INFINITY, 0usize);
            let tok = dobi::tokenizer::ByteTokenizer;
            for (i, opt) in sample.options.iter().enumerate() {
                let (w, st, en) = tok.encode_pair(&sample.question, opt, s, 32);
                let mut tokens = vec![0i32; b * s];
                let mut image = vec![0f32; b * model.img_dim];
                for r in 0..b {
                    tokens[r * s..(r + 1) * s].copy_from_slice(&w);
                    image[r * model.img_dim..(r + 1) * model.img_dim]
                        .copy_from_slice(&sample.image);
                }
                let logits = model.forward(b, s, &tokens, Some(&image))?;
                let nll = dobi::mathx::span_nll(&logits, &tokens, s, model.vocab, 0, st, en);
                if nll < best.0 {
                    best = (nll, i);
                }
            }
            println!("\nQ: {}", sample.question);
            for (i, o) in sample.options.iter().enumerate() {
                let mark = if i == best.1 { "->" } else { "  " };
                let truth = if i == sample.answer { "(truth)" } else { "" };
                println!("{mark} {o} {truth}");
            }
        }
    }
    Ok(())
}
