//! Quickstart: load a Dobi-SVD-compressed model and talk to it.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use dobi::bench::artifacts_dir;
use dobi::config::Manifest;
use dobi::evalx;
use dobi::runtime::Runtime;

fn main() -> Result<()> {
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    println!("artifacts: {} variants, profile `{}`", manifest.variants.len(), manifest.profile);

    let rt = Runtime::new()?;
    println!("PJRT platform: {}", rt.platform());

    // Dense baseline and the Dobi-SVD 0.6 compression of the same model.
    let (b, s) = (manifest.eval_batch, manifest.eval_seq);
    let dense = rt.load_variant(&manifest, "llama-nano/dense", Some(&[(b, s)]))?;
    let dobi = rt.load_variant(&manifest, "llama-nano/dobi_60", Some(&[(b, s)]))?;

    println!(
        "\ndense: {:.2} MB on device | dobi-0.6: {:.2} MB stored ({}x smaller on disk)",
        dense.stats.weight_bytes as f64 / 1e6,
        dobi.variant.bytes as f64 / 1e6,
        dense.stats.payload_bytes / dobi.stats.payload_bytes.max(1),
    );

    for (name, model) in [("dense", &dense), ("dobi-0.6", &dobi)] {
        let ppl = evalx::perplexity(model, &manifest, "wiki-syn")?;
        println!("{name}: wiki-syn perplexity = {ppl:.3}");
    }

    println!("\n--- sampled text (dobi-0.6) ---");
    let text = evalx::generate(&dobi, b, s, "The ", 120, 0.8, 7)?;
    println!("The {text}");
    Ok(())
}
