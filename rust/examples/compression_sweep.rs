//! Compression sweep: live-measured perplexity of every method at every
//! ratio — a miniature of the paper's Table 2, regenerated end to end
//! from the artifacts (rust runtime, not the python reference numbers).
//!
//! ```bash
//! make artifacts && cargo run --release --example compression_sweep
//! ```

use anyhow::Result;
use dobi::bench::{artifacts_dir, Table};
use dobi::config::Manifest;
use dobi::evalx;
use dobi::runtime::Runtime;

fn main() -> Result<()> {
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let (b, s) = (manifest.eval_batch, manifest.eval_seq);
    let rt = Runtime::new()?;

    let methods = ["dense", "dobi", "dobi-noremap", "weight_svd", "asvd", "svdllm",
                   "wanda_sp", "flap", "llm_pruner"];
    let mut table = Table::new(
        "PPL vs compression ratio, llama-nano on wiki-syn (lower is better)",
        &["method", "r=1.0", "r=0.8", "r=0.6", "r=0.4"],
    );
    for method in methods {
        let mut row = vec![method.to_string()];
        for ratio in [1.0, 0.8, 0.6, 0.4] {
            let hit = manifest.variants.iter().find(|v| {
                v.model == "llama-nano" && v.method == method && v.kernel == "xla"
                    && (v.ratio - ratio).abs() < 1e-6
            });
            match hit {
                Some(v) if v.hlo_for(b, s).is_some() => {
                    let model = rt.load_variant(&manifest, &v.id, Some(&[(b, s)]))?;
                    let ppl = evalx::perplexity(&model, &manifest, "wiki-syn")?;
                    row.push(format!("{ppl:.2}"));
                }
                _ => row.push("-".into()),
            }
        }
        table.row(row);
    }
    table.print();
    println!("\npaper shape to check: dobi row dominates every other compression row,\n\
              and the gap widens as the ratio drops (Table 2's 9.95 vs 53.74 vs 57057).");
    Ok(())
}
