//! Edge deployment scenario (the paper's Titan-Xp 12GB experiment,
//! Table 10): a device whose memory fits the compressed models but not
//! the dense one.  Compute is measured on the real runtime; only the
//! host->device paging of non-resident weights is modeled (memsim).
//!
//! ```bash
//! make artifacts && cargo run --release --example edge_deploy
//! ```

use anyhow::Result;
use dobi::bench::{artifacts_dir, bench, Table};
use dobi::config::Manifest;
use dobi::memsim::DeviceModel;
use dobi::runtime::Runtime;

fn main() -> Result<()> {
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let (b, s) = (manifest.eval_batch, manifest.eval_seq);
    let rt = Runtime::new()?;

    for device in [DeviceModel::titan_nano(), DeviceModel::a100_nano()] {
        let mut table = Table::new(
            &format!("{} (capacity {:.1} MB, {:.0} MB/s host link)",
                     device.name, device.capacity as f64 / 1e6, device.bandwidth / 1e6),
            &["variant", "MB", "resident", "paged MB/pass", "tok/s", "speedup"],
        );
        let mut base: Option<f64> = None;
        for id in ["llama-nano/dense", "llama-nano/dobi_80", "llama-nano/dobi_60",
                   "llama-nano/dobi_40"] {
            let Ok(v) = manifest.variant(id) else { continue };
            if v.hlo_for(b, s).is_none() {
                continue;
            }
            let model = rt.load_variant(&manifest, id, Some(&[(b, s)]))?;
            let tokens = vec![32i32; b * s];
            let r = bench(id, 1, 5, || {
                model.forward(b, s, &tokens, None).unwrap();
            });
            let sim = device.tokens_per_s(v.bytes, r.stats.mean, b * s);
            if base.is_none() {
                base = Some(sim.tokens_per_s);
            }
            table.row(vec![
                id.to_string(),
                format!("{:.2}", v.bytes as f64 / 1e6),
                format!("{}", sim.resident),
                format!("{:.2}", sim.paged_bytes as f64 / 1e6),
                format!("{:.1}", sim.tokens_per_s),
                format!("{:.1}x", sim.tokens_per_s / base.unwrap()),
            ]);
        }
        table.print();
    }
    println!("\npaper shape: dense pays the paging tax (2.09 tok/s on Titan Xp), every\n\
              Dobi ratio is resident and runs at full compute speed (23-26 tok/s, 11-12x).");
    Ok(())
}
