//! End-to-end serving driver (the DESIGN.md validation run).
//!
//! Loads the dense model plus every Dobi-SVD ratio, serves a batched
//! request workload through the full coordinator stack (router -> dynamic
//! batcher -> PJRT executor), and reports throughput + latency percentiles
//! per variant, plus a quality check (perplexity) so the speed numbers are
//! attached to a model that demonstrably still works.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_requests
//! ```

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use dobi::bench::{artifacts_dir, Table};
use dobi::config::{EngineConfig, Manifest};
use dobi::coordinator::Engine;
use dobi::evalx;
use dobi::mathx::summarize;
use dobi::runtime::Runtime;
use dobi::tokenizer::ByteTokenizer;

fn main() -> Result<()> {
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let (b, s) = (manifest.eval_batch, manifest.eval_seq);

    let ids: Vec<String> = ["dense", "dobi_80", "dobi_60", "dobi_40"]
        .iter()
        .map(|m| format!("llama-nano/{m}"))
        .filter(|id| manifest.variant(id).is_ok())
        .collect();

    println!("loading {} variants through the engine...", ids.len());
    let cfg = EngineConfig { max_batch: b, batch_deadline_us: 2_000, queue_depth: 512, workers: 1,
                             ..Default::default() };
    let engine = Arc::new(Engine::start(dir.clone(), &ids, cfg, Some(vec![(b, s)]))?);

    // Quality first: PPL per variant on a dedicated runtime (the engine's
    // runtime is busy serving).
    let rt = Runtime::new()?;
    let mut ppls = Vec::new();
    for id in &ids {
        let model = rt.load_variant(&manifest, id, Some(&[(b, s)]))?;
        ppls.push(evalx::perplexity(&model, &manifest, "wiki-syn")?);
    }

    // Workload: 4 client threads x 32 requests per variant.
    let mut table = Table::new(
        "end-to-end serving (coordinator + PJRT, 4 clients)",
        &["variant", "ratio", "MB", "wiki-ppl", "req/s", "tok-windows/s",
          "p50 ms", "p99 ms", "mean batch"],
    );
    for (id, ppl) in ids.iter().zip(&ppls) {
        let n_clients = 4;
        let per_client = 32;
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for c in 0..n_clients {
            let eng = engine.clone();
            let id = id.clone();
            handles.push(std::thread::spawn(move || {
                let tok = ByteTokenizer;
                let mut lat = Vec::new();
                for i in 0..per_client {
                    let win = tok.encode_window(
                        &format!("client {c} asks question number {i} about the "), s, 32);
                    let resp = eng.infer(&id, win, None).expect("infer");
                    lat.push(resp.total_s);
                }
                lat
            }));
        }
        let mut lats = Vec::new();
        for h in handles {
            lats.extend(h.join().unwrap());
        }
        let wall = t0.elapsed().as_secs_f64();
        let stats = summarize(&lats);
        let n = (n_clients * per_client) as f64;
        let v = manifest.variant(id)?;
        let es = engine.stats();
        table.row(vec![
            id.clone(),
            format!("{:.1}", v.ratio),
            format!("{:.2}", v.bytes as f64 / 1e6),
            format!("{ppl:.2}"),
            format!("{:.1}", n / wall),
            format!("{:.1}", n * s as f64 / wall),
            format!("{:.2}", stats.p50 * 1e3),
            format!("{:.2}", stats.p99 * 1e3),
            format!("{:.2}", es.mean_batch),
        ]);
    }
    table.print();

    let st = engine.stats();
    println!("engine totals: served={} batches={} rejects={}", st.served, st.batches,
             st.queue_full_rejects);
    engine.shutdown();
    Ok(())
}
