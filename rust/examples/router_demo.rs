//! Routing-policy demo: the coordinator picking a serving variant
//! per-request — explicit, by requested compression ratio, and by device
//! memory budget (the policy that backs the edge-deployment story).
//!
//! ```bash
//! make artifacts && cargo run --release --example router_demo
//! ```

use std::sync::Arc;

use anyhow::Result;
use dobi::bench::{artifacts_dir, Table};
use dobi::config::{EngineConfig, Manifest};
use dobi::coordinator::Engine;
use dobi::tokenizer::ByteTokenizer;

fn main() -> Result<()> {
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let (b, s) = (manifest.eval_batch, manifest.eval_seq);
    let ids: Vec<String> = ["dense", "dobi_80", "dobi_60", "dobi_40"]
        .iter()
        .map(|m| format!("llama-nano/{m}"))
        .filter(|id| manifest.variant(id).is_ok())
        .collect();
    let engine = Arc::new(Engine::start(dir, &ids, EngineConfig { max_batch: b, ..Default::default() },
                                        Some(vec![(b, s)]))?);
    let router = engine.router();

    let mut t = Table::new("by-ratio routing", &["requested ratio", "routed to"]);
    for want in [1.0, 0.75, 0.55, 0.3] {
        let v = router.by_ratio("llama-nano", want).unwrap();
        t.row(vec![format!("{want:.2}"), v.id.clone()]);
    }
    t.print();

    let mut t2 = Table::new("by-memory routing (device budget)", &["budget MB", "routed to"]);
    for budget_mb in [16.0, 4.0, 2.5, 1.5] {
        let hit = router.by_memory("llama-nano", (budget_mb * 1e6) as usize);
        t2.row(vec![
            format!("{budget_mb:.1}"),
            hit.map(|v| v.id.clone()).unwrap_or_else(|| "(nothing fits)".into()),
        ]);
    }
    t2.print();

    // Route one live request through the chosen variant.
    let tok = ByteTokenizer;
    let pick = router.by_memory("llama-nano", 4_000_000).map(|v| v.id.clone());
    if let Some(id) = pick {
        let win = tok.encode_window("a memory-budgeted request ", s, 32);
        let resp = engine.infer(&id, win, None)?;
        println!("\nrouted live request -> {id}: {} logits, {:.2} ms",
                 resp.output.len(), resp.total_s * 1e3);
    }
    engine.shutdown();
    Ok(())
}
