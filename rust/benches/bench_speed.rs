//! Speed/deployment benches: the native low-rank factorized-vs-dense
//! sweep and the native compression pipeline (no artifacts needed),
//! Fig 4 (throughput vs batch & seqlen), Table 10 (constrained-device
//! speedup), Table 12 (VLM speed), Table 23 (speed vs PTQ), engine
//! overhead, and the batcher-policy ablation (DESIGN.md §5.5).
//!
//! The native sections additionally emit machine-readable
//! `BENCH_speed.json` / `BENCH_compress.json` (ratio, tok/s, params
//! kept) so the perf trajectory is tracked across PRs.
//!
//!   cargo bench --bench bench_speed -- lowrank compress alloc decode spec trace fig4 table10 table12 table23 engine batcher

use std::sync::Arc;

use dobi::bench::{artifacts_available, artifacts_dir, bench, bench_for, write_bench_json,
                  Table};
use dobi::config::{AllocMode, CompressConfig, EngineConfig, Manifest, Precision};
use dobi::coordinator::Engine;
use dobi::json::Json;
use dobi::lowrank::synth::{tiny_model, TinyDims};
use dobi::lowrank::{matmul, set_decode_threads, Factor, FactorizedLinear, FactorizedModel};
use dobi::mathx::XorShift;
use dobi::memsim::DeviceModel;
use dobi::runtime::Runtime;
use dobi::tokenizer::ByteTokenizer;

fn main() {
    let filter: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let want = |name: &str| filter.is_empty() || filter.iter().any(|f| f == name);

    // Native sections first: they run on a fresh checkout, no artifacts.
    if want("lowrank") { lowrank_sweep(); }
    if want("compress") { compress_bench(); }
    if want("alloc") { alloc_bench(); }
    if want("decode") { decode_bench(); }
    if want("spec") { spec_bench(); }
    if want("trace") { trace_bench(); }

    if !artifacts_available() {
        eprintln!("[bench_speed] artifacts not built — PJRT sections skipped \
                   (run `make artifacts`)");
        return;
    }
    let m = Manifest::load(&artifacts_dir()).expect("manifest");
    let rt = Runtime::new().expect("pjrt");

    if want("fig4") { fig4(&m, &rt); }
    if want("table10") { table10(&m, &rt); }
    if want("table12") { table12(&m, &rt); }
    if want("table23") { table23(&m, &rt); }
    if want("engine") { engine_overhead(&m, &rt); }
    if want("batcher") { batcher_ablation(&m); }
    if want("loadcurve") { load_curve(&m); }
}

/// Native backend: dense-equivalent vs rank-k factorized apply at several
/// rank fractions, per factor precision.  The acceptance shape: wall-clock
/// tracks the FLOP ratio `k(m+n)/mn`, and f16/int8 factors pay a bounded
/// decode overhead for their 2x/4x memory saving.
fn lowrank_sweep() {
    let rows = 256; // eval_batch 4 x eval_seq 64 token rows
    let dims: [(&str, usize, usize); 3] =
        [("wq/wk/wv/wo", 192, 192), ("w_gate/w_up", 192, 512), ("w_down", 512, 192)];
    let mut t = Table::new(
        &format!("Native low-rank — factorized vs dense matmul ({rows} rows)"),
        &["matrix", "m x n", "frac", "k", "dense ms", "f32 ms", "f16 ms",
          "int8 ms", "flop ratio", "speedup"],
    );
    let mut rng = XorShift::new(11);
    let mut randv = |n: usize, s: f32| -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * s).collect()
    };
    let mut json_rows: Vec<Json> = Vec::new();
    for (name, m, n) in dims {
        let w = Factor::f32(m, n, randv(m * n, 0.05));
        let x = randv(rows * m, 1.0);
        let dense = bench_for("dense", 0.15, 5, || {
            matmul(&x, rows, &w);
        });
        for frac in [0.2f64, 0.4, 0.6] {
            let k = ((m.min(n) as f64 * frac).round() as usize).max(1);
            let w1 = randv(m * k, 0.1);
            let w2 = randv(k * n, 0.1);
            let mk = |w1f: Factor, w2f: Factor| {
                FactorizedLinear::new(name, w1f, w2f).expect("factor dims")
            };
            let lin32 = mk(Factor::f32(m, k, w1.clone()), Factor::f32(k, n, w2.clone()));
            let lin16 = mk(Factor::f16_from_f32(m, k, &w1), Factor::f16_from_f32(k, n, &w2));
            let lin8 = mk(Factor::i8_cols_from_f32(m, k, &w1), Factor::i8_rows_from_f32(k, n, &w2));
            let r32 = bench_for("f32", 0.15, 5, || {
                lin32.apply(&x, rows);
            });
            let r16 = bench_for("f16", 0.15, 5, || {
                lin16.apply(&x, rows);
            });
            let r8 = bench_for("i8", 0.15, 5, || {
                lin8.apply(&x, rows);
            });
            let flop_ratio = (k * (m + n)) as f64 / (m * n) as f64;
            t.row(vec![
                name.to_string(),
                format!("{m}x{n}"),
                format!("{frac:.1}"),
                format!("{k}"),
                format!("{:.3}", dense.stats.mean * 1e3),
                format!("{:.3}", r32.stats.mean * 1e3),
                format!("{:.3}", r16.stats.mean * 1e3),
                format!("{:.3}", r8.stats.mean * 1e3),
                format!("{flop_ratio:.2}"),
                format!("{:.2}x", dense.stats.mean / r32.stats.mean),
            ]);
            json_rows.push(Json::obj(vec![
                ("matrix", Json::Str(name.to_string())),
                ("m", Json::Num(m as f64)),
                ("n", Json::Num(n as f64)),
                ("rank_fraction", Json::Num(frac)),
                ("k", Json::Num(k as f64)),
                ("dense_ms", Json::Num(dense.stats.mean * 1e3)),
                ("f32_ms", Json::Num(r32.stats.mean * 1e3)),
                ("f16_ms", Json::Num(r16.stats.mean * 1e3)),
                ("i8_ms", Json::Num(r8.stats.mean * 1e3)),
                ("flop_ratio", Json::Num(flop_ratio)),
                ("rows_per_s", Json::Num(rows as f64 / r32.stats.mean)),
                ("speedup_vs_dense", Json::Num(dense.stats.mean / r32.stats.mean)),
            ]));
        }
    }
    t.print();
    let doc = Json::obj(vec![
        ("bench", Json::Str("lowrank_sweep".into())),
        ("rows", Json::Num(rows as f64)),
        ("results", Json::Arr(json_rows)),
    ]);
    match write_bench_json("speed", &doc) {
        Ok(p) => println!("[bench_speed] wrote {}", p.display()),
        Err(e) => eprintln!("[bench_speed] could not write BENCH_speed.json: {e}"),
    }
    println!("shape to check: f32 speedup tracks 1/flop-ratio (k(m+n) vs mn); f16/int8\n\
              factors trade a bounded decode cost for 2x/4x resident-memory savings.");
}

/// Native compression pipeline sweep: synth dense nano model compressed
/// at several global ratios; reports achieved ratio, params kept, eval
/// CE delta vs dense, and serve-side tokens/s of the compressed model —
/// emitted both as a table and as `BENCH_compress.json`.  A telemetry
/// pass re-runs the 0.4-ratio point with the compress trace ring enabled
/// vs `--trace-buffer 0` and folds the per-phase wall-clock shares plus
/// the instrumentation overhead number into the same JSON doc.
fn compress_bench() {
    use dobi::compress::{calib, compress_model, compress_model_traced, eval_loss,
                         write_artifacts, CompressTelemetry};
    let dims = TinyDims::nano();
    let dense = tiny_model(dims, 0, false);
    let corpus = calib::synth_calib_tokens(256, 4096, 19);
    let (b, s) = (2usize, 32usize);
    let tokens: Vec<i32> = (0..(b * s) as i32).map(|i| i % 251).collect();
    let l_dense = eval_loss(&dense, &corpus, b, 16, 6, 5).expect("dense eval");
    let dense_fwd = bench_for("dense-fwd", 0.2, 3, || {
        dense.forward(b, s, &tokens, None).unwrap();
    });
    let dense_tps = dense_fwd.throughput((b * s) as f64);
    let mut t = Table::new(
        "Native compression — dobi compress sweep (synth nano, q8)",
        &["ratio", "achieved", "params kept", "compress s", "CE delta", "tok/s", "vs dense"],
    );
    let mut json_rows: Vec<Json> = Vec::new();
    for ratio in [0.2f64, 0.4, 0.6] {
        let cfg = CompressConfig { ratio, precision: Precision::Q8, ..Default::default() };
        let t0 = std::time::Instant::now();
        let art = compress_model(&dense, "tiny", &cfg, &corpus).expect("compress");
        let compress_s = t0.elapsed().as_secs_f64();
        // measure the REAL deliverable: the q8 store round-tripped through
        // the writer + native loader (int8 decode cost and quantization
        // drift included), not the in-memory f32 reference twin
        let dir = std::env::temp_dir()
            .join(format!("dobi_bench_compress_{}", (ratio * 100.0).round() as usize));
        let _ = std::fs::remove_dir_all(&dir);
        write_artifacts(&dir, &art).expect("artifacts");
        let m = Manifest::load(&dir).expect("manifest");
        let v = m.variant(&art.variant_id).expect("variant");
        let store = m.open_store(v).expect("store");
        let model = dobi::lowrank::FactorizedModel::from_store(&m.models["tiny"], v, &store)
            .expect("load");
        let ce = eval_loss(&model, &corpus, b, 16, 6, 5).expect("eval");
        let fwd = bench_for("fwd", 0.2, 3, || {
            model.forward(b, s, &tokens, None).unwrap();
        });
        let tps = fwd.throughput((b * s) as f64);
        t.row(vec![
            format!("{ratio:.1}"),
            format!("{:.3}", art.achieved_ratio),
            format!("{}/{}", art.stored_params, art.total_params),
            format!("{compress_s:.2}"),
            format!("{:+.3}", ce - l_dense),
            format!("{tps:.0}"),
            format!("{:.2}x", tps / dense_tps),
        ]);
        json_rows.push(Json::obj(vec![
            ("ratio", Json::Num(ratio)),
            ("achieved_ratio", Json::Num(art.achieved_ratio)),
            ("params_kept", Json::Num(art.stored_params as f64)),
            ("total_params", Json::Num(art.total_params as f64)),
            ("payload_bytes", Json::Num(art.payload_bytes as f64)),
            ("compress_seconds", Json::Num(compress_s)),
            ("eval_ce", Json::Num(ce)),
            ("eval_ce_dense", Json::Num(l_dense)),
            ("tokens_per_s", Json::Num(tps)),
            ("speedup_vs_dense", Json::Num(tps / dense_tps)),
        ]));
    }
    t.print();

    // Telemetry pass: the same 0.4-ratio compression with the trace ring
    // disabled (`--trace-buffer 0` — must record nothing) and enabled,
    // so the instrumentation overhead is a tracked number and the phase
    // wall-clock shares from the run report land in the bench JSON.
    let tel_cfg = CompressConfig { ratio: 0.4, precision: Precision::Q8, ..Default::default() };
    let off_tel = CompressTelemetry::disabled();
    let t0 = std::time::Instant::now();
    compress_model_traced(&dense, "tiny", &tel_cfg, &corpus, &off_tel).expect("compress off");
    let off_s = t0.elapsed().as_secs_f64();
    assert!(!off_tel.trace.enabled(), "trace-buffer 0 must disable the ring");
    assert_eq!(off_tel.trace.recorded(), 0, "disabled compress trace ring must record nothing");
    let on_tel = CompressTelemetry::new(65_536, false);
    let t0 = std::time::Instant::now();
    let traced = compress_model_traced(&dense, "tiny", &tel_cfg, &corpus, &on_tel)
        .expect("compress on");
    let on_s = t0.elapsed().as_secs_f64();
    let events = on_tel.trace.drain(false);
    let overhead_pct = (on_s - off_s) / off_s.max(1e-9) * 100.0;
    let mut pt = Table::new(
        "Compression telemetry — phase wall-clock shares (ratio 0.4, q8)",
        &["phase", "seconds", "share"],
    );
    let mut phase_rows: Vec<Json> = Vec::new();
    for p in &traced.run_report.phases {
        pt.row(vec![
            p.phase.clone(),
            format!("{:.3}", p.seconds),
            format!("{:.1}%", p.share * 100.0),
        ]);
        phase_rows.push(Json::obj(vec![
            ("phase", Json::Str(p.phase.clone())),
            ("seconds", Json::Num(p.seconds)),
            ("share", Json::Num(p.share)),
        ]));
    }
    pt.print();
    println!("[bench_speed] compress trace off {off_s:.2}s, on {on_s:.2}s \
              ({overhead_pct:+.1}% overhead), {} events recorded", events.len());

    let doc = Json::obj(vec![
        ("bench", Json::Str("compress_sweep".into())),
        ("model", Json::obj(vec![
            ("vocab", Json::Num(dims.vocab as f64)),
            ("d_model", Json::Num(dims.d as f64)),
            ("n_layers", Json::Num(dims.layers as f64)),
            ("d_ff", Json::Num(dims.ff as f64)),
        ])),
        ("dense_tokens_per_s", Json::Num(dense_tps)),
        ("results", Json::Arr(json_rows)),
        ("telemetry", Json::obj(vec![
            ("ratio", Json::Num(0.4)),
            ("disabled_seconds", Json::Num(off_s)),
            ("enabled_seconds", Json::Num(on_s)),
            ("overhead_pct", Json::Num(overhead_pct)),
            ("events_recorded", Json::Num(events.len() as f64)),
            ("phase_shares", Json::Arr(phase_rows)),
        ])),
    ]);
    match write_bench_json("compress", &doc) {
        Ok(p) => println!("[bench_speed] wrote {}", p.display()),
        Err(e) => eprintln!("[bench_speed] could not write BENCH_compress.json: {e}"),
    }
    println!("shape to check: tok/s grows as the ratio drops (rank-k matmuls do less\n\
              work); CE delta grows smoothly — the compression/quality frontier.\n\
              telemetry: the disabled ring records zero events and the overhead stays\n\
              in the noise band; SVD + calibration dominate the phase shares.");
}

/// Allocation-mode sweep: greedy waterfill vs the learned differentiable
/// truncation-position optimizer at matched stored-param budgets on the
/// synth nano twin.  Each ratio compresses once with the waterfill, then
/// hands the learned allocator the waterfill's *achieved* budget — the
/// apples-to-apples comparison the acceptance test pins: eval CE of the
/// learned allocation must never exceed the waterfill's (the rounding is
/// waterfill-guarded, so ties collapse to identical plans).  Emits
/// `BENCH_alloc.json` with eval CE, the discrete surrogate losses, which
/// rounding the guard picked, and the optimizer wall-clock.
fn alloc_bench() {
    use dobi::compress::{calib, compress_model, eval_loss, AllocPick};
    let dims = TinyDims::nano();
    let dense = tiny_model(dims, 0, false);
    let corpus = calib::synth_calib_tokens(256, 4096, 19);
    let l_dense = eval_loss(&dense, &corpus, 2, 16, 6, 5).expect("dense eval");
    let mut t = Table::new(
        "Allocation modes — waterfill vs learned at matched budgets (synth nano, f32)",
        &["ratio", "budget", "wf CE", "learned CE", "delta", "picked", "train s"],
    );
    let mut json_rows: Vec<Json> = Vec::new();
    for ratio in [0.3f64, 0.4, 0.6] {
        let wf_cfg = CompressConfig { ratio, precision: Precision::F32, ..Default::default() };
        let wf = compress_model(&dense, "tiny", &wf_cfg, &corpus).expect("waterfill");
        let learned_cfg = CompressConfig {
            ratio,
            budget: Some(wf.stored_params), // matched stored-param budget
            precision: Precision::F32,
            alloc: AllocMode::Learned,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let learned = compress_model(&dense, "tiny", &learned_cfg, &corpus).expect("learned");
        let train_s = t0.elapsed().as_secs_f64();
        assert!(learned.stored_params <= wf.stored_params,
                "learned overspent the matched budget");
        let ce_wf = eval_loss(&wf.reference, &corpus, 2, 16, 6, 5).expect("wf eval");
        let ce_learned =
            eval_loss(&learned.reference, &corpus, 2, 16, 6, 5).expect("learned eval");
        let report = learned.train_report.as_ref().expect("learned report");
        let picked = match report.picked {
            AllocPick::Learned => "learned",
            AllocPick::Waterfill => "waterfill",
        };
        t.row(vec![
            format!("{ratio:.1}"),
            format!("{}", wf.stored_params),
            format!("{ce_wf:.4}"),
            format!("{ce_learned:.4}"),
            format!("{:+.5}", ce_learned - ce_wf),
            picked.to_string(),
            format!("{train_s:.2}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("ratio", Json::Num(ratio)),
            ("budget_params", Json::Num(wf.stored_params as f64)),
            ("waterfill_eval_ce", Json::Num(ce_wf)),
            ("learned_eval_ce", Json::Num(ce_learned)),
            ("eval_ce_delta", Json::Num(ce_learned - ce_wf)),
            ("dense_eval_ce", Json::Num(l_dense)),
            ("waterfill_surrogate", Json::Num(report.waterfill_surrogate)),
            ("learned_surrogate", Json::Num(report.learned_surrogate)),
            ("picked", Json::Str(picked.into())),
            ("train_iters", Json::Num(report.iters as f64)),
            ("train_seconds", Json::Num(train_s)),
            ("lambda", Json::Num(report.lambda)),
        ]));
    }
    t.print();
    let doc = Json::obj(vec![
        ("bench", Json::Str("alloc_sweep".into())),
        ("model", Json::obj(vec![
            ("vocab", Json::Num(dims.vocab as f64)),
            ("d_model", Json::Num(dims.d as f64)),
            ("n_layers", Json::Num(dims.layers as f64)),
            ("d_ff", Json::Num(dims.ff as f64)),
        ])),
        ("dense_eval_ce", Json::Num(l_dense)),
        ("results", Json::Arr(json_rows)),
    ]);
    match write_bench_json("alloc", &doc) {
        Ok(p) => println!("[bench_speed] wrote {}", p.display()),
        Err(e) => eprintln!("[bench_speed] could not write BENCH_alloc.json: {e}"),
    }
    println!("shape to check: learned CE <= waterfill CE at every matched budget (the\n\
              guard makes SURROGATE regressions impossible and ties emit the greedy plan\n\
              bit-for-bit, so CE deltas are 0 unless the optimizer strictly improves the\n\
              surrogate — where better CE is expected, not structurally guaranteed).");
}

/// Incremental decode vs the sliding-window loop it replaced: prefill a
/// 256-token prompt, then decode 64 tokens — once through a KV-cached
/// session (`forward_kv`: O(len) attention + single-row logits head per
/// token) and once the old way (a full forward over the whole window per
/// token).  Run on the synth dense nano model AND its `dobi compress` q8
/// twin, so the table shows the compounding: low-rank factors shrink the
/// matmuls, the KV runtime stops re-running them.  Emits
/// `BENCH_decode.json`; acceptance floor is >= 3x tokens/s with KV reuse.
fn decode_bench() {
    use dobi::compress::{calib, compress_model};
    use dobi::mathx::argmax;
    use dobi::serve::DecodeSession;

    let dims = TinyDims::nano();
    let dense = tiny_model(dims, 0, false);
    let corpus = calib::synth_calib_tokens(dims.vocab, 4096, 23);
    let cfg = CompressConfig { ratio: 0.4, precision: Precision::Q8, ..Default::default() };
    let art = compress_model(&dense, "tiny", &cfg, &corpus).expect("compress");
    // round-trip the q8 store through the writer + native loader so the
    // measured decode includes the real int8 tile-decode cost
    let dir = std::env::temp_dir().join("dobi_bench_decode_q8");
    let _ = std::fs::remove_dir_all(&dir);
    dobi::compress::write_artifacts(&dir, &art).expect("artifacts");
    let m = Manifest::load(&dir).expect("manifest");
    let v = m.variant(&art.variant_id).expect("variant");
    let store = m.open_store(v).expect("store");
    let q8_model = dobi::lowrank::FactorizedModel::from_store(&m.models["tiny"], v, &store)
        .expect("load");
    let q8 = &q8_model;

    let (prefill_len, n_decode) = (256usize, 64usize);
    let prompt: Vec<i32> = (0..prefill_len as i32).map(|i| (i * 31 + 7) % 251).collect();
    let mut t = Table::new(
        &format!("Incremental decode — {prefill_len}-token prefill + {n_decode}-token decode"),
        &["model", "path", "prefill ms", "decode tok/s", "speedup", "max |Δlogit|"],
    );
    let mut json_rows: Vec<Json> = Vec::new();
    for (name, model) in [("dense", &dense), ("dobi_40 q8", q8)] {
        // KV-cached session: prefill once, then one step per token.
        let mut session = DecodeSession::new(1, name, model, prefill_len + n_decode + 1);
        let t0 = std::time::Instant::now();
        let mut logits = session.prefill(model, &prompt, None).expect("prefill");
        let prefill_s = t0.elapsed().as_secs_f64();
        let mut kv_tokens = Vec::with_capacity(n_decode);
        let t0 = std::time::Instant::now();
        for _ in 0..n_decode {
            let next = argmax(&logits) as i32;
            kv_tokens.push(next);
            logits = session.step(model, next).expect("step");
        }
        let kv_s = t0.elapsed().as_secs_f64();
        let kv_tps = n_decode as f64 / kv_s;

        // Sliding-window baseline: the old serve path — a full forward
        // over the entire context per generated token.
        let vocab = model.vocab;
        let mut ctx = prompt.clone();
        let mut win_tokens = Vec::with_capacity(n_decode);
        let mut drift = 0f32;
        let t0 = std::time::Instant::now();
        for _ in 0..n_decode {
            let s = ctx.len();
            let out = model.forward(1, s, &ctx, None).expect("window forward");
            let last = &out[(s - 1) * vocab..s * vocab];
            let next = argmax(last) as i32;
            win_tokens.push(next);
            ctx.push(next);
        }
        let win_s = t0.elapsed().as_secs_f64();
        let win_tps = n_decode as f64 / win_s;
        assert_eq!(kv_tokens, win_tokens,
                   "{name}: KV decode diverged from the sliding-window reference");
        // parity telemetry: final-step logits vs the full forward's
        let want = {
            let s = ctx.len();
            let out = model.forward(1, s, &ctx, None).expect("parity forward");
            out[(s - 1) * vocab..s * vocab].to_vec()
        };
        for (a, b) in logits.iter().zip(&want) {
            drift = drift.max((a - b).abs());
        }

        let speedup = kv_tps / win_tps;
        t.row(vec![
            name.to_string(),
            "kv vs window".into(),
            format!("{:.2}", prefill_s * 1e3),
            format!("{kv_tps:.0} vs {win_tps:.0}"),
            format!("{speedup:.1}x"),
            format!("{drift:.2e}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("model", Json::Str(name.to_string())),
            ("prefill_tokens", Json::Num(prefill_len as f64)),
            ("decode_tokens", Json::Num(n_decode as f64)),
            ("prefill_seconds", Json::Num(prefill_s)),
            ("kv_tokens_per_s", Json::Num(kv_tps)),
            ("window_tokens_per_s", Json::Num(win_tps)),
            ("speedup_kv_vs_window", Json::Num(speedup)),
            ("max_abs_logit_drift", Json::Num(drift as f64)),
        ]));
    }
    t.print();

    // Fused multi-session decode: N concurrent prefilled sessions advanced
    // through ONE batched trunk walk per tick (`DecodeSession::step_many`)
    // vs stepping them one at a time — the weight-tile decode amortization
    // the serve scheduler gets under concurrent load.  Token streams must
    // be identical (the fused step is bit-identical to serial).
    // Acceptance floor: >= 1.5x tokens/s at 4 concurrent q8 sessions.
    let n_sessions = 4usize;
    let (fuse_prefill, fuse_decode) = (64usize, 64usize);
    let mut ft = Table::new(
        &format!("Fused multi-session decode — {n_sessions} sessions, \
                  {fuse_prefill}-token prefill + {fuse_decode}-token decode"),
        &["model", "serial tok/s", "fused tok/s", "speedup"],
    );
    let mut fused_rows: Vec<Json> = Vec::new();
    for (name, model) in [("dense", &dense), ("dobi_40 q8", q8)] {
        let (serial_tps, serial_tokens) =
            run_serial_sessions(model, n_sessions, fuse_prefill, fuse_decode);
        let (fused_tps, fused_tokens) =
            run_fused_sessions(model, n_sessions, fuse_prefill, fuse_decode);
        assert_eq!(serial_tokens, fused_tokens,
                   "{name}: fused decode diverged from serial stepping");
        let speedup = fused_tps / serial_tps;
        ft.row(vec![
            name.to_string(),
            format!("{serial_tps:.0}"),
            format!("{fused_tps:.0}"),
            format!("{speedup:.2}x"),
        ]);
        fused_rows.push(Json::obj(vec![
            ("model", Json::Str(name.to_string())),
            ("sessions", Json::Num(n_sessions as f64)),
            ("prefill_tokens", Json::Num(fuse_prefill as f64)),
            ("decode_tokens", Json::Num(fuse_decode as f64)),
            ("serial_tokens_per_s", Json::Num(serial_tps)),
            ("fused_tokens_per_s", Json::Num(fused_tps)),
            ("speedup_fused_vs_serial", Json::Num(speedup)),
        ]));
    }
    ft.print();

    // Decode-thread sweep over the fused step on a wider dense synth model
    // (the nano trunk's matmuls sit below the threaded kernel's work
    // floor, so threads only engage once the weight tiles are big enough
    // to pay for the scoped-thread spawn).
    let wide_dims = TinyDims { vocab: 256, d: 192, heads: 4, layers: 2, ff: 512 };
    let wide = tiny_model(wide_dims, 0, false);
    let mut tt = Table::new(
        &format!("Fused decode thread sweep — d={} dense synth, {n_sessions} sessions",
                 wide_dims.d),
        &["decode threads", "fused tok/s", "vs 1 thread"],
    );
    let mut thread_rows: Vec<Json> = Vec::new();
    let mut one_thread_tps = 0f64;
    for threads in [1usize, 2, 4] {
        set_decode_threads(threads);
        let (tps, _) = run_fused_sessions(&wide, n_sessions, fuse_prefill, fuse_decode);
        set_decode_threads(1);
        if threads == 1 {
            one_thread_tps = tps;
        }
        tt.row(vec![
            format!("{threads}"),
            format!("{tps:.0}"),
            format!("{:.2}x", tps / one_thread_tps),
        ]);
        thread_rows.push(Json::obj(vec![
            ("threads", Json::Num(threads as f64)),
            ("d_model", Json::Num(wide_dims.d as f64)),
            ("sessions", Json::Num(n_sessions as f64)),
            ("fused_tokens_per_s", Json::Num(tps)),
            ("speedup_vs_one_thread", Json::Num(tps / one_thread_tps)),
        ]));
    }
    tt.print();

    let doc = Json::obj(vec![
        ("bench", Json::Str("decode_sweep".into())),
        ("model", Json::obj(vec![
            ("vocab", Json::Num(dims.vocab as f64)),
            ("d_model", Json::Num(dims.d as f64)),
            ("n_layers", Json::Num(dims.layers as f64)),
            ("d_ff", Json::Num(dims.ff as f64)),
        ])),
        ("results", Json::Arr(json_rows)),
        ("fused_results", Json::Arr(fused_rows)),
        ("thread_sweep", Json::Arr(thread_rows)),
    ]);
    match write_bench_json("decode", &doc) {
        Ok(p) => println!("[bench_speed] wrote {}", p.display()),
        Err(e) => eprintln!("[bench_speed] could not write BENCH_decode.json: {e}"),
    }
    println!("shape to check: >= 3x tokens/s from KV reuse (acceptance floor; expect far\n\
              more — the window path pays O(len^2) attention AND a (len, vocab) logits\n\
              head per token), with zero token divergence and ~1e-5 logit drift.\n\
              fused floor: >= 1.5x fused-vs-serial at 4 concurrent q8 sessions (tile\n\
              decode amortizes across the stacked rows), identical token streams.");
}

/// Self-speculative decode sweep: compressed drafts (ratio 0.3/0.4/0.6,
/// q8, round-tripped through the store writer + native loader) propose
/// k in {2, 4, 8} tokens per round for the dense target, which verifies
/// each round in ONE batched multi-row trunk walk.  Token parity with
/// pure dense decode is asserted at every grid point (greedy speculative
/// output is bit-identical by construction), then `BENCH_spec.json`
/// records acceptance rate and end-to-end tok/s vs the pure-dense
/// baseline.  Acceptance floor: tok/s >= 1.0x the baseline at the best
/// (ratio, k) point.  The acceptance-rate column doubles as a paper
/// measurement: how much of the dense greedy distribution survives SVD
/// truncation at each ratio.
fn spec_bench() {
    use dobi::compress::{calib, compress_model, write_artifacts};
    use dobi::mathx::argmax;
    use dobi::serve::{DecodeSession, SpecDecoder};

    let dims = TinyDims::nano();
    let dense = tiny_model(dims, 0, false);
    let corpus = calib::synth_calib_tokens(dims.vocab, 4096, 29);
    let (prefill_len, n_decode) = (64usize, 64usize);
    let cap = prefill_len + n_decode + 16;
    let prompt: Vec<i32> = (0..prefill_len as i32).map(|i| (i * 17 + 3) % 251).collect();

    // Pure-dense baseline: prefill + greedy serial decode, end to end.
    let pure_decode = || -> (Vec<i32>, f64) {
        let t0 = std::time::Instant::now();
        let mut s = DecodeSession::new(1, "ref", &dense, cap);
        let mut logits = s.prefill(&dense, &prompt, None).expect("prefill");
        let mut out = Vec::with_capacity(n_decode);
        while out.len() < n_decode {
            let t = argmax(&logits) as i32;
            out.push(t);
            if out.len() < n_decode {
                logits = s.step(&dense, t).expect("step");
            }
        }
        (out, t0.elapsed().as_secs_f64())
    };
    let (want_tokens, _) = pure_decode(); // warm
    let (check, base_s) = pure_decode();
    assert_eq!(check, want_tokens);
    let base_tps = n_decode as f64 / base_s;

    let mut t = Table::new(
        &format!("Self-speculative decode — dense target, q8 drafts \
                  ({prefill_len}-token prefill + {n_decode}-token decode)"),
        &["draft ratio", "k", "accept rate", "tok/s", "vs dense"],
    );
    let mut json_rows: Vec<Json> = Vec::new();
    let mut best_speedup = 0f64;
    for ratio in [0.3f64, 0.4, 0.6] {
        // round-trip the draft through the writer + loader so the measured
        // draft steps include the real int8 tile-decode cost
        let cfg = CompressConfig { ratio, precision: Precision::Q8, ..Default::default() };
        let art = compress_model(&dense, "tiny", &cfg, &corpus).expect("compress");
        let dir = std::env::temp_dir()
            .join(format!("dobi_bench_spec_{}", (ratio * 100.0).round() as usize));
        let _ = std::fs::remove_dir_all(&dir);
        write_artifacts(&dir, &art).expect("artifacts");
        let m = Manifest::load(&dir).expect("manifest");
        let v = m.variant(&art.variant_id).expect("variant");
        let store = m.open_store(v).expect("store");
        let draft = FactorizedModel::from_store(&m.models["tiny"], v, &store).expect("load");

        for k in [2usize, 4, 8] {
            let t0 = std::time::Instant::now();
            let mut target = DecodeSession::new(1, "tgt", &dense, cap);
            let logits = target.prefill(&dense, &prompt, None).expect("target prefill");
            let mut dsess = DecodeSession::new(2, "dft", &draft, cap);
            dsess.prefill(&draft, &prompt, None).expect("draft prefill");
            let mut spec = SpecDecoder::new(dsess, k);
            let mut out = vec![argmax(&logits) as i32];
            let (mut proposed, mut accepted) = (0usize, 0usize);
            'decode: while out.len() < n_decode {
                let last = *out.last().unwrap();
                let round = spec
                    .round(&draft, &dense, &mut target, last)
                    .expect("spec round");
                proposed += round.proposed;
                accepted += round.accepted;
                for row in &round.rows {
                    out.push(argmax(row) as i32);
                    if out.len() >= n_decode {
                        break 'decode;
                    }
                }
            }
            let spec_s = t0.elapsed().as_secs_f64();
            assert_eq!(out, want_tokens,
                       "speculative decode diverged from pure dense (ratio {ratio}, k {k})");
            let rate = accepted as f64 / proposed.max(1) as f64;
            let tps = n_decode as f64 / spec_s;
            let speedup = tps / base_tps;
            best_speedup = best_speedup.max(speedup);
            t.row(vec![
                format!("{ratio:.1}"),
                format!("{k}"),
                format!("{rate:.2}"),
                format!("{tps:.0}"),
                format!("{speedup:.2}x"),
            ]);
            json_rows.push(Json::obj(vec![
                ("draft_ratio", Json::Num(ratio)),
                ("k", Json::Num(k as f64)),
                ("proposed", Json::Num(proposed as f64)),
                ("accepted", Json::Num(accepted as f64)),
                ("acceptance_rate", Json::Num(rate)),
                ("tokens_per_s", Json::Num(tps)),
                ("speedup_vs_dense", Json::Num(speedup)),
                ("token_parity", Json::Bool(true)),
            ]));
        }
    }
    t.print();
    let doc = Json::obj(vec![
        ("bench", Json::Str("spec_sweep".into())),
        ("model", Json::obj(vec![
            ("vocab", Json::Num(dims.vocab as f64)),
            ("d_model", Json::Num(dims.d as f64)),
            ("n_layers", Json::Num(dims.layers as f64)),
            ("d_ff", Json::Num(dims.ff as f64)),
        ])),
        ("prefill_tokens", Json::Num(prefill_len as f64)),
        ("decode_tokens", Json::Num(n_decode as f64)),
        ("baseline_tokens_per_s", Json::Num(base_tps)),
        ("best_speedup_vs_dense", Json::Num(best_speedup)),
        ("results", Json::Arr(json_rows)),
    ]);
    match write_bench_json("spec", &doc) {
        Ok(p) => println!("[bench_speed] wrote {}", p.display()),
        Err(e) => eprintln!("[bench_speed] could not write BENCH_spec.json: {e}"),
    }
    println!("shape to check: every grid point emits the pure-dense token stream\n\
              bit-for-bit; acceptance rate climbs with draft ratio (more of the dense\n\
              greedy distribution survives milder truncation) and the best (ratio, k)\n\
              point clears 1.0x the pure-dense baseline ({best_speedup:.2}x this run).");
}

/// Observability bench: drive the serve scheduler end to end twice over
/// the same synthetic two-variant fixture — once with the
/// request-lifecycle trace ring enabled, once with `trace_buffer: 0` —
/// and compare end-to-end tokens/s.  The disabled path must record
/// nothing (that's the zero-cost contract `--trace-buffer 0` promises);
/// the enabled run's drained ring is folded into per-phase time shares
/// (queue wait / admission / prefill / step / spec draft / spec verify /
/// eviction) — the "where does a served token's wall-clock go"
/// breakdown — and `BENCH_trace.json` records both so the tracing
/// overhead is tracked across PRs.
fn trace_bench() {
    use dobi::config::ServeConfig;
    use dobi::lowrank::synth::{tiny_manifest_json, tiny_store_tensors, SynthStyle};
    use dobi::serve::{ServeRuntime, SpecParams};
    use dobi::storage::write_store;

    let dims = TinyDims { vocab: 256, d: 24, heads: 2, layers: 2, ff: 32 };
    let dir = std::env::temp_dir().join("dobi_bench_trace");
    std::fs::create_dir_all(&dir).expect("bench fixture dir");
    write_store(&dir.join("dense.dobiw"),
                &tiny_store_tensors(dims, 0, SynthStyle::DenseF32)).expect("dense store");
    write_store(&dir.join("q8.dobiw"),
                &tiny_store_tensors(dims, 0, SynthStyle::FactorQ8)).expect("q8 store");
    std::fs::write(
        dir.join("manifest.json"),
        tiny_manifest_json(dims, 0, &[
            ("tiny/dense", "dense", 1.0, "dense.dobiw"),
            ("tiny/q8", "factorized", 0.6, "q8.dobiw"),
        ]),
    )
    .expect("manifest");

    let variants = ["tiny/dense".to_string(), "tiny/q8".to_string()];
    let (n_requests, max_tokens) = (12usize, 32usize);
    let prompt: Vec<i32> = (0..48).map(|i| (i * 13 + 7) % 251).collect();

    // One workload pass against a fresh runtime: n_requests greedy
    // generates, alternating plain and speculative so the ring sees the
    // full span vocabulary.  Returns (tokens/s, runtime) with the
    // runtime still live so the caller can drain its ring.
    let run_pass = |trace_buffer: usize| -> (f64, ServeRuntime) {
        let rt = ServeRuntime::start(
            dir.clone(),
            &variants,
            ServeConfig { max_sessions: 4, trace_buffer, ..Default::default() },
        )
        .expect("serve runtime");
        let t0 = std::time::Instant::now();
        let mut tokens = 0usize;
        for i in 0..n_requests {
            let out = if i % 2 == 0 {
                rt.generate("tiny/dense", &prompt, max_tokens, 0.0, 1)
                    .expect("generate")
            } else {
                rt.generate_spec("tiny/dense", &prompt, max_tokens,
                                 SpecParams { draft: "tiny/q8".into(), k: 4 })
                    .expect("spec generate")
            };
            tokens += out.len();
        }
        (tokens as f64 / t0.elapsed().as_secs_f64(), rt)
    };

    // Warm each mode once (store mmap, lazy allocs), then measure.
    let (_, w) = run_pass(0);
    w.shutdown();
    let (off_tps, off_rt) = run_pass(0);
    assert!(!off_rt.trace().enabled(), "trace_buffer: 0 must disable the ring");
    assert_eq!(off_rt.trace().recorded(), 0,
               "disabled trace ring must record nothing");
    off_rt.shutdown();
    let (_, w) = run_pass(65_536);
    w.shutdown();
    let (on_tps, on_rt) = run_pass(65_536);
    let events = on_rt.trace().drain(false);
    let requests_traced =
        events.iter().filter(|e| e.name == "request").count();
    assert_eq!(requests_traced, n_requests,
               "every request must close with a `request` span");
    on_rt.shutdown();

    // Per-phase time shares over the leaf spans ("request" is the
    // umbrella covering the whole lifecycle — counting it would double
    // every microsecond).
    let mut by_name: Vec<(&'static str, u64, usize)> = Vec::new();
    for e in &events {
        if e.name == "request" {
            continue;
        }
        match by_name.iter_mut().find(|(n, _, _)| *n == e.name) {
            Some((_, us, cnt)) => {
                *us += e.dur_us;
                *cnt += 1;
            }
            None => by_name.push((e.name, e.dur_us, 1)),
        }
    }
    by_name.sort_by(|a, b| b.1.cmp(&a.1));
    let total_us: u64 = by_name.iter().map(|(_, us, _)| *us).sum();

    let mut t = Table::new(
        &format!("Serve trace — phase time shares over {n_requests} requests \
                  ({max_tokens} tokens each, half speculative)"),
        &["phase", "spans", "total ms", "share"],
    );
    let mut share_rows: Vec<Json> = Vec::new();
    for (name, us, cnt) in &by_name {
        let share = *us as f64 / total_us.max(1) as f64;
        t.row(vec![
            name.to_string(),
            format!("{cnt}"),
            format!("{:.2}", *us as f64 / 1e3),
            format!("{:.1}%", share * 100.0),
        ]);
        share_rows.push(Json::obj(vec![
            ("phase", Json::Str(name.to_string())),
            ("spans", Json::Num(*cnt as f64)),
            ("total_us", Json::Num(*us as f64)),
            ("share", Json::Num(share)),
        ]));
    }
    t.print();
    let overhead_pct = (off_tps - on_tps) / off_tps * 100.0;
    println!("[bench_speed] trace off {off_tps:.0} tok/s, on {on_tps:.0} tok/s \
              ({overhead_pct:+.1}% overhead), {} events recorded", events.len());

    let doc = Json::obj(vec![
        ("bench", Json::Str("trace_overhead".into())),
        ("model", Json::obj(vec![
            ("vocab", Json::Num(dims.vocab as f64)),
            ("d_model", Json::Num(dims.d as f64)),
            ("n_layers", Json::Num(dims.layers as f64)),
            ("d_ff", Json::Num(dims.ff as f64)),
        ])),
        ("requests", Json::Num(n_requests as f64)),
        ("max_tokens", Json::Num(max_tokens as f64)),
        ("disabled_tokens_per_s", Json::Num(off_tps)),
        ("enabled_tokens_per_s", Json::Num(on_tps)),
        ("overhead_pct", Json::Num(overhead_pct)),
        ("events_recorded", Json::Num(events.len() as f64)),
        ("requests_traced", Json::Num(requests_traced as f64)),
        ("phase_shares", Json::Arr(share_rows)),
    ]);
    match write_bench_json("trace", &doc) {
        Ok(p) => println!("[bench_speed] wrote {}", p.display()),
        Err(e) => eprintln!("[bench_speed] could not write BENCH_trace.json: {e}"),
    }
    println!("shape to check: the disabled ring records zero events, tracing overhead\n\
              stays in the noise band (single-digit percent, often negative at this\n\
              model size), and the phase shares put step/prefill — not queue_wait or\n\
              evict_sweep — at the top of the table.");
}

/// Prefill `n` decode sessions with distinct deterministic prompts;
/// returns (sessions, per-session next-token logits).  Shared by the
/// serial and fused halves of the fused-decode bench so both step the
/// exact same state.
fn prefill_sessions(model: &FactorizedModel, n: usize, prefill: usize,
                    n_decode: usize) -> (Vec<dobi::serve::DecodeSession>, Vec<Vec<f32>>) {
    use dobi::serve::DecodeSession;
    let mut sessions = Vec::with_capacity(n);
    let mut logits = Vec::with_capacity(n);
    for i in 0..n {
        let prompt: Vec<i32> =
            (0..prefill as i32).map(|t| (t * 13 + 7 * i as i32 + 1) % 251).collect();
        let mut s = DecodeSession::new(i as u64, "bench", model, prefill + n_decode + 1);
        logits.push(s.prefill(model, &prompt, None).expect("prefill"));
        sessions.push(s);
    }
    (sessions, logits)
}

/// Greedy-decode `n_decode` tokens per session, one serial step per
/// session per tick.  Returns (tokens/s over all sessions, token streams).
fn run_serial_sessions(model: &FactorizedModel, n: usize, prefill: usize,
                       n_decode: usize) -> (f64, Vec<Vec<i32>>) {
    use dobi::mathx::argmax;
    let (mut sessions, mut logits) = prefill_sessions(model, n, prefill, n_decode);
    let mut tokens = vec![Vec::new(); n];
    let t0 = std::time::Instant::now();
    for _ in 0..n_decode {
        for i in 0..n {
            let next = argmax(&logits[i]) as i32;
            tokens[i].push(next);
            logits[i] = sessions[i].step(model, next).expect("serial step");
        }
    }
    ((n * n_decode) as f64 / t0.elapsed().as_secs_f64(), tokens)
}

/// Greedy-decode `n_decode` tokens per session through the fused
/// multi-session step.  Returns (tokens/s over all sessions, streams).
fn run_fused_sessions(model: &FactorizedModel, n: usize, prefill: usize,
                      n_decode: usize) -> (f64, Vec<Vec<i32>>) {
    use dobi::mathx::argmax;
    use dobi::serve::DecodeSession;
    let (mut sessions, mut logits) = prefill_sessions(model, n, prefill, n_decode);
    let mut tokens = vec![Vec::new(); n];
    let t0 = std::time::Instant::now();
    for _ in 0..n_decode {
        let next: Vec<i32> = logits.iter().map(|l| argmax(l) as i32).collect();
        for (stream, &t) in tokens.iter_mut().zip(&next) {
            stream.push(t);
        }
        let mut refs: Vec<&mut DecodeSession> = sessions.iter_mut().collect();
        logits = DecodeSession::step_many(model, &mut refs, &next).expect("fused step");
    }
    ((n * n_decode) as f64 / t0.elapsed().as_secs_f64(), tokens)
}

/// Latency vs offered load (open-loop Poisson arrivals) — the serving
/// curve a deployment actually cares about; shows the knee where the
/// single executor saturates and backpressure engages.
fn load_curve(m: &Manifest) {
    use dobi::bench::loadgen::poisson_load;
    let (b, s) = (m.eval_batch, m.eval_seq);
    let id = "llama-nano/dobi_60".to_string();
    if m.variant(&id).map(|v| v.hlo_for(b, s).is_none()).unwrap_or(true) {
        return;
    }
    // calibrate: measure a saturated batch to place the sweep
    let cfg = EngineConfig { max_batch: b, batch_deadline_us: 2000, queue_depth: 64, workers: 1,
                             ..Default::default() };
    let engine = Arc::new(
        Engine::start(artifacts_dir(), &[id.clone()], cfg, Some(vec![(b, s)])).unwrap());
    let mut t = Table::new(
        "Latency vs offered load (Poisson open loop, dobi-0.6)",
        &["offered req/s", "achieved", "rejected", "p50 ms", "p99 ms"],
    );
    // rough capacity probe
    let probe = poisson_load(&engine, &id, s, 50.0, std::time::Duration::from_millis(800), 1);
    let cap = probe.achieved_rps.max(5.0);
    for frac in [0.25, 0.5, 0.8, 1.0, 1.5] {
        let rate = cap * frac;
        let r = poisson_load(&engine, &id, s, rate,
                             std::time::Duration::from_secs(3), 7 + frac as u64);
        t.row(vec![
            format!("{:.1}", r.offered_rps),
            format!("{:.1}", r.achieved_rps),
            format!("{}", r.rejected),
            format!("{:.2}", r.latency.p50 * 1e3),
            format!("{:.2}", r.latency.p99 * 1e3),
        ]);
    }
    t.print();
    engine.shutdown();
    println!("shape: flat latency below the knee, p99 blow-up + rejects past saturation\n\
              (bounded queues shed load instead of collapsing).");
}

/// Fig 4: tokens/s vs batch size (a, seq=32) and vs seq len (b, batch=4)
/// for dense + every Dobi ratio — live measurements.
fn fig4(m: &Manifest, rt: &Runtime) {
    let ids = ["llama-nano/dense", "llama-nano/dobi_80", "llama-nano/dobi_60",
               "llama-nano/dobi_40"];
    for (title, shapes) in [
        ("Fig 4a — tokens/s vs batch (seq=32)",
         vec![(1usize, 32usize), (2, 32), (4, 32), (8, 32), (16, 32)]),
        ("Fig 4b — tokens/s vs seq (batch=4)",
         vec![(4, 16), (4, 32), (4, 64), (4, 128)]),
    ] {
        let mut t = Table::new(title, &["variant", "shape", "ms/fwd", "tokens/s", "vs dense"]);
        let mut dense_tps: std::collections::BTreeMap<(usize, usize), f64> = Default::default();
        for id in ids {
            let Ok(v) = m.variant(id) else { continue };
            let avail: Vec<(usize, usize)> =
                shapes.iter().copied().filter(|&(b, s)| v.hlo_for(b, s).is_some()).collect();
            if avail.is_empty() {
                continue;
            }
            let model = rt.load_variant(m, id, Some(&avail)).expect("load");
            for &(b, s) in &avail {
                let tokens = vec![32i32; b * s];
                let r = bench_for(id, 0.4, 3, || {
                    model.forward(b, s, &tokens, None).unwrap();
                });
                let tps = r.throughput((b * s) as f64);
                if id.ends_with("dense") {
                    dense_tps.insert((b, s), tps);
                }
                let rel = dense_tps.get(&(b, s)).map(|d| tps / d).unwrap_or(f64::NAN);
                t.row(vec![
                    id.to_string(),
                    format!("{b}x{s}"),
                    format!("{:.2}", r.stats.mean * 1e3),
                    format!("{tps:.0}"),
                    format!("{rel:.2}x"),
                ]);
            }
        }
        t.print();
    }
    println!("paper shape: compressed > dense everywhere; the advantage grows with batch\n\
              size and shrinks with seq length (attention is O(S^2) and uncompressed).");
}

/// Table 10: the Titan-Xp scenario — measured compute + modeled paging.
fn table10(m: &Manifest, rt: &Runtime) {
    let device = DeviceModel::titan_nano();
    let (b, s) = (m.eval_batch, m.eval_seq);
    let mut t = Table::new(
        &format!("Table 10 — {} (dense does not fit)", device.name),
        &["ratio", "MB", "resident", "tok/s", "speedup"],
    );
    let mut base = None;
    for id in ["llama-nano/dense", "llama-nano/dobi_80", "llama-nano/dobi_60",
               "llama-nano/dobi_40"] {
        let Ok(v) = m.variant(id) else { continue };
        if v.hlo_for(b, s).is_none() {
            continue;
        }
        let model = rt.load_variant(m, id, Some(&[(b, s)])).expect("load");
        let tokens = vec![32i32; b * s];
        let r = bench(id, 1, 6, || {
            model.forward(b, s, &tokens, None).unwrap();
        });
        // Dense deployments on the constrained device hold fp16 weights;
        // dobi variants hold their remapped bytes.
        let sim = device.tokens_per_s(v.bytes, r.stats.mean, b * s);
        if base.is_none() {
            base = Some(sim.tokens_per_s);
        }
        t.row(vec![
            format!("{:.1}", v.ratio),
            format!("{:.2}", v.bytes as f64 / 1e6),
            format!("{}", sim.resident),
            format!("{:.1}", sim.tokens_per_s),
            format!("{:.1}x", sim.tokens_per_s / base.unwrap()),
        ]);
    }
    t.print();
    println!("paper shape: 1x -> ~11-12x once the model is resident (2.09 -> 23-26 tok/s).");
}

/// Table 12: VLM serving speed at bz=1 and bz=4 (paper used 1 and 16).
/// Multimodal forwards run on the literal-args execute path (the
/// buffer-args path aborts in xla_extension 0.5.1 — EXPERIMENTS.md).
fn table12(m: &Manifest, rt: &Runtime) {
    let (b, s) = (m.eval_batch, m.eval_seq);
    let mut t = Table::new("Table 12 — VLM (vlm-nano) speed",
                           &["ratio", "bz=1 tok/s", "bz=4 tok/s"]);
    for id in ["vlm-nano/dense", "vlm-nano/dobi_80", "vlm-nano/dobi_60", "vlm-nano/dobi_40"] {
        let Ok(v) = m.variant(id) else { continue };
        let mut row = vec![format!("{:.1}", v.ratio)];
        for (bb, ss) in [(1usize, 64usize), (b, s)] {
            if v.hlo_for(bb, ss).is_none() {
                row.push("-".into());
                continue;
            }
            let model = rt.load_variant(m, id, Some(&[(bb, ss)])).expect("load");
            let tokens = vec![32i32; bb * ss];
            let image = vec![0.1f32; bb * model.img_dim];
            let r = bench_for(id, 0.3, 3, || {
                model.forward(bb, ss, &tokens, Some(&image)).unwrap();
            });
            row.push(format!("{:.0}", r.throughput((bb * ss) as f64)));
        }
        t.row(row);
    }
    t.print();
    println!("paper shape: modest speedups growing with batch (2.1% -> 20.1% at 0.4).");
}

/// Table 23: Dobi vs PTQ'd dense — PPL, size, and measured speed.
/// (Our int-quantized variants serve dequantized f32 weights; the paper's
/// point — factorized fp beats dequantize-on-the-fly int — is made by the
/// GFLOPs column: rank-k matmuls genuinely do less work.)
fn table23(m: &Manifest, rt: &Runtime) {
    let (b, s) = (m.eval_batch, m.eval_seq);
    let mut t = Table::new("Table 23 — Dobi vs quantized dense (size / speed / flops)",
                           &["variant", "MB", "tok/s", "rel-matmul-flops"]);
    let minfo = &m.models["llama-nano"];
    let dense_flops: f64 = 7.0 * (minfo.d_model * minfo.d_model) as f64; // schematic per-layer
    for id in ["llama-nano/dense", "llama-nano/dobi-int8_60", "llama-nano/dobi_80",
               "llama-nano/dobi_60", "llama-nano/dobi_40"] {
        let Ok(v) = m.variant(id) else { continue };
        if v.hlo_for(b, s).is_none() {
            continue;
        }
        let model = rt.load_variant(m, id, Some(&[(b, s)])).expect("load");
        let tokens = vec![32i32; b * s];
        let r = bench_for(id, 0.3, 3, || {
            model.forward(b, s, &tokens, None).unwrap();
        });
        // relative matmul work from the stored rank structure
        let rel = if v.kind == "factorized" {
            v.stored_params as f64 / minfo.total_params as f64
        } else {
            1.0
        };
        t.row(vec![
            id.to_string(),
            format!("{:.2}", v.bytes as f64 / 1e6),
            format!("{:.0}", r.throughput((b * s) as f64)),
            format!("{rel:.2}"),
            ]);
        let _ = dense_flops;
    }
    t.print();
    println!("paper shape: Dobi at larger size still faster than int-quantized dense\n\
              (fewer FLOPs, no dequant on the serve path).");
}

/// Engine overhead: coordinator+batcher path vs bare runtime calls.
fn engine_overhead(m: &Manifest, rt: &Runtime) {
    let (b, s) = (m.eval_batch, m.eval_seq);
    let id = "llama-nano/dense";
    if m.variant(id).map(|v| v.hlo_for(b, s).is_none()).unwrap_or(true) {
        return;
    }
    let model = rt.load_variant(m, id, Some(&[(b, s)])).expect("load");
    let tokens = vec![32i32; b * s];
    let bare = bench("bare", 2, 10, || {
        model.forward(b, s, &tokens, None).unwrap();
    });

    let cfg = EngineConfig { max_batch: b, batch_deadline_us: 1000, queue_depth: 256, workers: 1,
                             ..Default::default() };
    let engine = Arc::new(
        Engine::start(artifacts_dir(), &[id.to_string()], cfg, Some(vec![(b, s)])).unwrap());
    let tok = ByteTokenizer;
    let win = tok.encode_window("the quick brown fox ", s, 32);
    // saturate with b concurrent clients so every executable call is full
    let r = bench("engine", 1, 6, || {
        let mut rxs = Vec::new();
        for _ in 0..b {
            rxs.push(engine.submit(id, win.clone(), None).unwrap());
        }
        for rx in rxs {
            rx.recv().unwrap();
        }
    });
    let mut t = Table::new("Engine overhead (batched path vs bare executable)",
                           &["path", "ms per full batch", "overhead"]);
    t.row(vec!["bare runtime".into(), format!("{:.3}", bare.stats.mean * 1e3), "-".into()]);
    t.row(vec![
        "engine (b clients)".into(),
        format!("{:.3}", r.stats.mean * 1e3),
        format!("{:.1}%", 100.0 * (r.stats.mean - bare.stats.mean) / bare.stats.mean),
    ]);
    t.print();
    engine.shutdown();
    println!("perf target (DESIGN.md §6): engine overhead < 5% of executable runtime.");
}

/// Batcher policy ablation: deadline sweep under a fixed open-loop load.
fn batcher_ablation(m: &Manifest) {
    let (b, s) = (m.eval_batch, m.eval_seq);
    let id = "llama-nano/dobi_60".to_string();
    if m.variant(&id).map(|v| v.hlo_for(b, s).is_none()).unwrap_or(true) {
        return;
    }
    let mut t = Table::new("Batcher ablation — deadline vs latency/throughput (16 clients)",
                           &["deadline us", "req/s", "p50 ms", "p99 ms", "mean batch"]);
    for deadline_us in [0u64, 500, 2000, 8000] {
        let cfg = EngineConfig { max_batch: b, batch_deadline_us: deadline_us,
                                 queue_depth: 1024, workers: 1, ..Default::default() };
        let engine = Arc::new(
            Engine::start(artifacts_dir(), &[id.clone()], cfg, Some(vec![(b, s)])).unwrap());
        let tok = ByteTokenizer;
        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        for c in 0..16 {
            let eng = engine.clone();
            let id2 = id.clone();
            let win = tok.encode_window(&format!("client {c} "), s, 32);
            handles.push(std::thread::spawn(move || {
                for _ in 0..8 {
                    eng.infer(&id2, win.clone(), None).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let st = engine.stats();
        t.row(vec![
            format!("{deadline_us}"),
            format!("{:.1}", 128.0 / wall),
            format!("{:.2}", st.p50_latency_s * 1e3),
            format!("{:.2}", st.p99_latency_s * 1e3),
            format!("{:.2}", st.mean_batch),
        ]);
        engine.shutdown();
    }
    t.print();
    println!("design ablation: tiny deadlines waste batch slots, huge ones pay latency;\n\
              the default (2000us) sits at the knee.");
}
