//! Regenerates the paper's *quality* tables from the artifacts, measured
//! live through the rust runtime (PPL / accuracy / storage accounting).
//!
//!   cargo bench --bench bench_tables              # everything
//!   cargo bench --bench bench_tables -- table2    # one table
//!
//! Table index (DESIGN.md §3): 1, 2, 3, 45, 6, 7, 8, 9, 11, 13, 15,
//! 16, 17, 18, 24.  Paper-vs-measured notes land in EXPERIMENTS.md.

use std::collections::BTreeMap;

use dobi::bench::{artifacts_available, artifacts_dir, fmt_f, Table};
use dobi::config::{Manifest, Variant};
use dobi::corpusio;
use dobi::evalx;
use dobi::runtime::{LoadedModel, Runtime};

struct Ctx {
    m: Manifest,
    rt: Runtime,
    b: usize,
    s: usize,
    ppl_cache: BTreeMap<(String, String), f64>,
    acc_cache: BTreeMap<String, Vec<evalx::SuiteResult>>,
}

impl Ctx {
    fn load(&self, id: &str) -> Option<LoadedModel> {
        let v = self.m.variant(id).ok()?;
        if v.hlo_for(self.b, self.s).is_none() {
            return None;
        }
        self.rt.load_variant(&self.m, id, Some(&[(self.b, self.s)])).ok()
    }

    fn ppl(&mut self, id: &str, corpus: &str) -> f64 {
        let key = (id.to_string(), corpus.to_string());
        if let Some(&p) = self.ppl_cache.get(&key) {
            return p;
        }
        let p = match self.load(id) {
            Some(model) => evalx::perplexity(&model, &self.m, corpus).unwrap_or(f64::NAN),
            None => f64::NAN,
        };
        self.ppl_cache.insert(key, p);
        p
    }

    fn suite_accs(&mut self, id: &str, limit: usize) -> Vec<evalx::SuiteResult> {
        if let Some(r) = self.acc_cache.get(id) {
            return r.clone();
        }
        let out = (|| -> Option<Vec<evalx::SuiteResult>> {
            let suites_file = self.m.suites_file.clone()?;
            let suites = corpusio::read_suites(&self.m.path(&suites_file)).ok()?;
            let model = self.load(id)?;
            let mut res = Vec::new();
            for s in &suites {
                res.push(evalx::run_suite(&model, s, self.b, self.s, limit).ok()?);
            }
            Some(res)
        })()
        .unwrap_or_default();
        self.acc_cache.insert(id.to_string(), out.clone());
        out
    }

    fn find<'a>(&'a self, model: &str, method: &str, ratio: f64) -> Option<&'a Variant> {
        self.m.variants.iter().find(|v| {
            v.model == model && v.method == method && v.kernel == "xla"
                && (v.ratio - ratio).abs() < 1e-6
        })
    }
}

const RATIOS: [f64; 3] = [0.8, 0.6, 0.4];
const TASK_LIMIT: usize = 24; // per-suite task budget per variant (CPU time)

fn main() {
    if !artifacts_available() {
        eprintln!("[bench_tables] artifacts not built — run `make artifacts` first");
        return;
    }
    let filter: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let want = |name: &str| filter.is_empty() || filter.iter().any(|f| f == name);
    let m = Manifest::load(&artifacts_dir()).expect("manifest");
    let (b, s) = (m.eval_batch, m.eval_seq);
    let mut ctx = Ctx { m, rt: Runtime::new().expect("pjrt"), b, s,
                        ppl_cache: BTreeMap::new(), acc_cache: BTreeMap::new() };

    if want("table1") { table1(&mut ctx); }
    if want("table2") { table2(&mut ctx); }
    if want("table3") { table3(&mut ctx); }
    if want("table45") { table45(&mut ctx); }
    if want("table6") { table6(&mut ctx); }
    if want("table7") { table7(&mut ctx); }
    if want("table8") { table8(&mut ctx); }
    if want("table9") { table9(&mut ctx); }
    if want("table11") { table11(&mut ctx); }
    if want("table13") { table13(&mut ctx); }
    if want("table15") { table15(&ctx); }
    if want("table16") { table16(&mut ctx); }
    if want("table17") { table17(&mut ctx); }
    if want("table18") { table18(&mut ctx); }
    if want("table24") { table24(&mut ctx); }
}

/// Table 1: truncate activations vs weights at identical positions.
/// Activation rows are the python-side oracle (the activation-truncation
/// "model" needs an SVD per eval batch — a training-time construct);
/// weight rows are re-measured live on the exported weight-SVD variants.
fn table1(ctx: &mut Ctx) {
    let mut t = Table::new("Table 1 — PPL, truncating activations vs weights (wiki-syn)",
                           &["Param Ratio", "1.0", "0.8", "0.6", "0.4"]);
    let a = ctx.m.analysis.get("table1").cloned();
    let row = |kind: &str, a: &Option<dobi::json::Json>| {
        let mut cells = vec![kind.to_string()];
        for r in ["1.0", "0.8", "0.6", "0.4"] {
            let v = a
                .as_ref()
                .and_then(|j| j.get(r))
                .and_then(|j| j.get(kind))
                .and_then(|j| j.as_f64())
                .unwrap_or(f64::NAN);
            cells.push(fmt_f(v, 2));
        }
        cells
    };
    t.row(row("activation", &a));
    // live weight-truncation row
    let mut cells = vec!["weight (live)".to_string()];
    cells.push(fmt_f(ctx.ppl("llama-nano/dense", "wiki-syn"), 2));
    for r in RATIOS {
        let id = ctx.find("llama-nano", "weight_svd", r).map(|v| v.id.clone());
        cells.push(match id {
            Some(id) => fmt_f(ctx.ppl(&id, "wiki-syn"), 2),
            None => "-".into(),
        });
    }
    t.row(cells);
    t.print();
    println!("paper shape: activation row degrades gracefully (5.68 -> 20.7), weight row\n\
              explodes (5.68 -> 105474).");
}

/// Table 2: main results — SVD-family methods x ratios, PPL on 3 corpora
/// + mean accuracy over the 7 task suites.
fn table2(ctx: &mut Ctx) {
    let mut t = Table::new(
        "Table 2 — Dobi-SVD vs SVD baselines (PPL wiki/ptb/c4, avg task acc)",
        &["ratio", "method", "wiki", "ptb", "c4", "avg-acc", "drop%"],
    );
    let dense_accs = ctx.suite_accs("llama-nano/dense", TASK_LIMIT);
    let dense_avg = avg_acc(&dense_accs);
    let mut dense_row = vec!["1.0".to_string(), "dense".to_string()];
    for c in ["wiki-syn", "ptb-syn", "c4-syn"] {
        dense_row.push(fmt_f(ctx.ppl("llama-nano/dense", c), 2));
    }
    dense_row.push(fmt_f(dense_avg, 3));
    dense_row.push("0.0".into());
    t.row(dense_row);
    for ratio in RATIOS {
        for method in ["asvd", "svdllm", "dobi-noremap", "dobi"] {
            let Some(v) = ctx.find("llama-nano", method, ratio) else { continue };
            let id = v.id.clone();
            let mut row = vec![format!("{ratio:.1}"), label(method).to_string()];
            for c in ["wiki-syn", "ptb-syn", "c4-syn"] {
                row.push(fmt_f(ctx.ppl(&id, c), 2));
            }
            let accs = ctx.suite_accs(&id, TASK_LIMIT);
            let avg = avg_acc(&accs);
            row.push(fmt_f(avg, 3));
            row.push(fmt_f(100.0 * (dense_avg - avg) / dense_avg.max(1e-9), 1));
            t.row(row);
        }
    }
    t.print();
    println!("paper shape: Dobi > Dobi* (no remap) > SVD-LLM > ASVD at every ratio; the\n\
              ordering gap widens at 0.4 (paper: 9.95 vs 46 vs 53.7 vs 57057 on wiki).");
}

fn label(m: &str) -> &str {
    match m {
        "dobi-noremap" => "Dobi-SVD*",
        "dobi" => "Dobi-SVD",
        "asvd" => "ASVD",
        "svdllm" => "SVD-LLM",
        _ => m,
    }
}

fn avg_acc(rs: &[evalx::SuiteResult]) -> f64 {
    if rs.is_empty() {
        return f64::NAN;
    }
    rs.iter().map(|r| r.accuracy).sum::<f64>() / rs.len() as f64
}

/// Table 3: vs pruning at ratio 0.8 on task suites.
fn table3(ctx: &mut Ctx) {
    let mut t = Table::new("Table 3 — vs pruning at ratio 0.8 (task accuracies)",
                           &["method", "avg-acc", "drop%", "wiki-ppl"]);
    let dense_avg = avg_acc(&ctx.suite_accs("llama-nano/dense", TASK_LIMIT));
    t.row(vec!["dense".into(), fmt_f(dense_avg, 3), "0.0".into(),
               fmt_f(ctx.ppl("llama-nano/dense", "wiki-syn"), 2)]);
    for method in ["llm_pruner", "wanda_sp", "flap", "dobi"] {
        let Some(v) = ctx.find("llama-nano", method, 0.8) else { continue };
        let id = v.id.clone();
        let avg = avg_acc(&ctx.suite_accs(&id, TASK_LIMIT));
        t.row(vec![
            method.into(),
            fmt_f(avg, 3),
            fmt_f(100.0 * (dense_avg - avg) / dense_avg.max(1e-9), 1),
            fmt_f(ctx.ppl(&id, "wiki-syn"), 2),
        ]);
    }
    t.print();
    println!("paper shape: Dobi matches/bests FLAP and LLM-Pruner at 0.8 (0% drop row).");
}

/// Tables 4/5: PPL across the model family (Llama-2/3 analogues).
fn table45(ctx: &mut Ctx) {
    for (model, paper) in [("llama2-nano", "Table 5 (Llama-2-7b analogue)"),
                           ("llama3-nano", "Table 4 (Llama-3-8b analogue)")] {
        if !ctx.m.models.contains_key(model) {
            continue;
        }
        let mut t = Table::new(&format!("{paper} — wiki-syn PPL"),
                               &["method", "0.8", "0.6", "0.4"]);
        for method in ["llm_pruner", "wanda_sp", "dobi"] {
            let mut row = vec![method.to_string()];
            for r in RATIOS {
                let id = ctx.find(model, method, r).map(|v| v.id.clone());
                row.push(match id {
                    Some(id) => fmt_f(ctx.ppl(&id, "wiki-syn"), 2),
                    None => "-".into(),
                });
            }
            t.row(row);
        }
        t.print();
    }
    println!("paper shape: Dobi rows flat-ish, pruning rows explode at 0.4 (121.5/160.5 vs 15.8).");
}

/// Table 6: the MMLU slot — harder mixed multi-choice suite vs ratio.
fn table6(ctx: &mut Ctx) {
    let Some(sf) = ctx.m.suites_file.clone() else { return };
    let Ok(suites) = corpusio::read_suites(&ctx.m.path(&sf)) else { return };
    let Some(mmlu) = suites.iter().find(|s| s.name == "mmlu-syn") else { return };
    let mut t = Table::new("Table 6 — mmlu-syn accuracy vs ratio", &["ratio", "acc"]);
    for (rname, id) in [("1.0", "llama-nano/dense".to_string()),
                        ("0.8", "llama-nano/dobi_80".to_string()),
                        ("0.6", "llama-nano/dobi_60".to_string()),
                        ("0.4", "llama-nano/dobi_40".to_string())] {
        let Some(model) = ctx.load(&id) else { continue };
        let r = evalx::run_suite(&model, mmlu, ctx.b, ctx.s, 30).unwrap();
        t.row(vec![rname.into(), fmt_f(r.accuracy, 3)]);
    }
    t.print();
    println!("paper shape: monotone degradation, steep at 0.4 (63.3 -> 28.2 on Llama-3.1).");
}

/// Table 7: accuracy vs pruning at low ratios on the model family.
fn table7(ctx: &mut Ctx) {
    for model in ["llama2-nano", "llama3-nano"] {
        if !ctx.m.models.contains_key(model) {
            continue;
        }
        let mut t = Table::new(&format!("Table 7 — {model} avg task acc vs pruning"),
                               &["ratio", "method", "avg-acc"]);
        for r in [0.6, 0.4] {
            for method in ["llm_pruner", "wanda_sp", "dobi"] {
                let Some(v) = ctx.find(model, method, r) else { continue };
                let id = v.id.clone();
                let avg = avg_acc(&ctx.suite_accs(&id, 16));
                t.row(vec![format!("{r:.1}"), method.into(), fmt_f(avg, 3)]);
            }
        }
        t.print();
    }
}

/// Table 8: remapping ablation.
fn table8(ctx: &mut Ctx) {
    let mut t = Table::new("Table 8 — remapping ablation (PPL)",
                           &["ratio", "variant", "wiki", "c4", "ptb"]);
    for r in RATIOS {
        for (name, method) in [("Remap(16bit)", "dobi-remap16"),
                               ("Remap(8+16bit)", "dobi"),
                               ("W/o Remap", "dobi-noremap")] {
            let Some(v) = ctx.find("llama-nano", method, r) else { continue };
            let id = v.id.clone();
            t.row(vec![
                format!("{:.0}%", r * 100.0),
                name.into(),
                fmt_f(ctx.ppl(&id, "wiki-syn"), 2),
                fmt_f(ctx.ppl(&id, "c4-syn"), 2),
                fmt_f(ctx.ppl(&id, "ptb-syn"), 2),
            ]);
        }
    }
    t.print();
    println!("paper shape: 16bit ~= 8+16bit (quantization is nearly free) << W/o Remap,\n\
              and the remap advantage explodes at 0.4 (9.95 vs 58.02).");
}

/// Tables 9/22/23 (quality+memory half): Dobi x PTQ.
fn table9(ctx: &mut Ctx) {
    let mut t = Table::new("Table 9/22 — Dobi-SVD composed with PTQ (wiki PPL, stored MB)",
                           &["ratio", "method", "ppl", "MB"]);
    for r in RATIOS {
        for method in ["dobi", "dobi+int8", "dobi+int4"] {
            let Some(v) = ctx.find("llama-nano", method, r) else { continue };
            let (id, bytes) = (v.id.clone(), v.bytes);
            t.row(vec![
                format!("{r:.1}"),
                method.into(),
                fmt_f(ctx.ppl(&id, "wiki-syn"), 2),
                format!("{:.2}", bytes as f64 / 1e6),
            ]);
        }
    }
    t.print();
    println!("paper shape: +int4 costs a little PPL for ~4x memory (9.95 -> 12.04, 6.8 -> 1.8GB).");
}

/// Table 11: VLM accuracy vs ratio.
fn table11(ctx: &mut Ctx) {
    let Some(vf) = ctx.m.vqa_file.clone() else { return };
    let Ok((_, samples)) = corpusio::read_vqa(&ctx.m.path(&vf)) else { return };
    let mut t = Table::new("Table 11 — VLM (vlm-nano) VQA accuracy vs ratio",
                           &["ratio", "acc", "MB"]);
    for (rname, id) in [("1.0", "vlm-nano/dense"), ("0.8", "vlm-nano/dobi_80"),
                        ("0.6", "vlm-nano/dobi_60"), ("0.4", "vlm-nano/dobi_40")] {
        let Ok(v) = ctx.m.variant(id) else { continue };
        let bytes = v.bytes;
        let Some(model) = ctx.load(id) else { continue };
        let r = evalx::run_vqa(&model, &samples, ctx.b, ctx.s, 40).unwrap();
        t.row(vec![rname.into(), fmt_f(r.accuracy, 3), format!("{:.2}", bytes as f64 / 1e6)]);
    }
    t.print();
    println!("paper shape: near-lossless to 0.6, visible drop at 0.4 (77.2 -> 70.8 avg).");
}

/// Table 13: VLA metrics vs ratio.
fn table13(ctx: &mut Ctx) {
    let Some(vf) = ctx.m.vla_file.clone() else { return };
    let Ok((_, samples)) = corpusio::read_vla(&ctx.m.path(&vf)) else { return };
    let mut t = Table::new("Table 13 — VLA (vla-nano): MSE / accuracy / memory",
                           &["ratio", "coords-mse", "angle-mse", "grip-acc", "MB"]);
    for (rname, id) in [("1.0", "vla-nano/dense"), ("0.8", "vla-nano/dobi_80"),
                        ("0.6", "vla-nano/dobi_60"), ("0.4", "vla-nano/dobi_40")] {
        let Ok(v) = ctx.m.variant(id) else { continue };
        let bytes = v.bytes;
        let Some(model) = ctx.load(id) else { continue };
        let r = evalx::run_vla(&model, &samples, ctx.b, ctx.s, 48).unwrap();
        t.row(vec![
            rname.into(),
            fmt_f(r.coords_mse, 4),
            fmt_f(r.angle_mse, 4),
            fmt_f(r.gripper_acc, 3),
            format!("{:.2}", bytes as f64 / 1e6),
        ]);
    }
    t.print();
    println!("paper shape: MSE creeps up slowly, accuracy ~flat to 0.6 (0.957 -> 0.930 at 0.4).");
}

/// Table 15: quantization error of the SVD factors per matrix kind
/// (python-side analysis: the factors pre-quantization live only in the
/// compression pipeline).
fn table15(ctx: &Ctx) {
    let Some(a) = ctx.m.analysis.get("table15") else { return };
    let mut t = Table::new("Table 15 — int8 error of SVD factors per matrix (layer 1)",
                           &["matrix", "MSE", "MAE"]);
    if let Some(obj) = a.as_obj() {
        for (k, v) in obj {
            t.row(vec![
                k.clone(),
                format!("{:.2e}", v.f64_of("mse")),
                format!("{:.2e}", v.f64_of("mae")),
            ]);
        }
    }
    t.print();
    println!("paper shape: all ~1e-7 MSE; FFN matrices slightly cleaner than attention.");
}

/// Table 16: trained k vs uniform k (both without remap).
fn table16(ctx: &mut Ctx) {
    let mut t = Table::new("Table 16 — differentiable-k vs uniform-k (no remap), PPL",
                           &["ratio", "variant", "wiki", "ptb", "c4"]);
    for r in RATIOS {
        for (name, method) in [("W/o Training", "uniform-noremap"),
                               ("Training", "dobi-noremap")] {
            let Some(v) = ctx.find("llama-nano", method, r) else { continue };
            let id = v.id.clone();
            t.row(vec![
                format!("{r:.1}"),
                name.into(),
                fmt_f(ctx.ppl(&id, "wiki-syn"), 2),
                fmt_f(ctx.ppl(&id, "ptb-syn"), 2),
                fmt_f(ctx.ppl(&id, "c4-syn"), 2),
            ]);
        }
    }
    t.print();
    println!("paper shape: trained k wins at every ratio, most at 0.4 (46.2 vs 58.0).");
}

/// Table 17: rank-perturbation sensitivity around dobi-0.4.
fn table17(ctx: &mut Ctx) {
    let base = ctx.ppl("llama-nano/dobi_40", "wiki-syn");
    let mut rows: Vec<(usize, String)> = ctx
        .m
        .variants
        .iter()
        .filter(|v| v.method == "dobi-perturb")
        .map(|v| (v.perturb_x.unwrap_or(0), v.id.clone()))
        .collect();
    rows.sort();
    if rows.is_empty() {
        return;
    }
    let mut t = Table::new("Table 17 — rank perturbation sensitivity (dobi-0.4, wiki-syn)",
                           &["adjust x", "adjust %", "ppl", "degradation %"]);
    t.row(vec!["0".into(), "0.000%".into(), fmt_f(base, 2), "0.0".into()]);
    for (x, id) in rows {
        let ppl = ctx.ppl(&id, "wiki-syn");
        t.row(vec![
            format!("{x}"),
            format!("{:.3}%", 100.0 * x as f64 / 192.0),
            fmt_f(ppl, 2),
            fmt_f(100.0 * (ppl - base) / base, 2),
        ]);
    }
    t.print();
    println!("paper shape: degradation grows superlinearly with the perturbation\n\
              (0.024% -> 0.7%, 1.2% -> 29% PPL hit) — trained ranks sit in a sharp optimum.");
}

/// Tables 18-21: the 13B-scale analogue (llama-nano-l).
fn table18(ctx: &mut Ctx) {
    if !ctx.m.models.contains_key("llama-nano-l") {
        return;
    }
    let mut t = Table::new("Tables 18-21 — llama-nano-l (13B analogue), wiki-syn PPL",
                           &["method", "0.8", "0.6", "0.4"]);
    for method in ["llm_pruner", "wanda_sp", "flap", "dobi"] {
        let mut row = vec![method.to_string()];
        for r in RATIOS {
            let id = ctx.find("llama-nano-l", method, r).map(|v| v.id.clone());
            row.push(match id {
                Some(id) => fmt_f(ctx.ppl(&id, "wiki-syn"), 2),
                None => "-".into(),
            });
        }
        t.row(row);
    }
    t.print();
    println!("paper shape: the larger model compresses MORE gracefully (5.43 at 0.8 on 13B).");
}

/// Tables 24/25: compressed-big vs uncompressed-small.
fn table24(ctx: &mut Ctx) {
    if !ctx.m.models.contains_key("llama-nano-l") {
        return;
    }
    let mut t = Table::new(
        "Table 24/25 — compressed larger model vs dense smaller model",
        &["model", "stored params", "wiki-ppl", "avg-acc"],
    );
    for id in ["llama-nano/dense", "llama-nano-l/dobi_60"] {
        let Ok(v) = ctx.m.variant(id) else { continue };
        let stored = v.stored_params;
        let id_s = id.to_string();
        let avg = avg_acc(&ctx.suite_accs(&id_s, 16));
        t.row(vec![
            id.into(),
            format!("{stored}"),
            fmt_f(ctx.ppl(&id_s, "wiki-syn"), 2),
            fmt_f(avg, 3),
        ]);
    }
    t.print();
    println!("paper shape: Dobi-compressed 13B beats dense 7B at comparable footprint.");
}
