//! Training-time figures regenerated from the compression pipeline's logs
//! (manifest `analysis`/`training` sections) plus live reconstructions
//! where the quantity is runtime-measurable:
//!
//!   fig3a  guided truncation (single vs multi layer)     [logs]
//!   fig3b  training batch size 8 vs 2                    [logs]
//!   fig3c  PCA vs IPCA memory                            [model + measured]
//!   fig7   loss/PPL vs training step                     [logs]
//!   fig8   k evolution per layer (+ figs 9/10 ratios)    [logs]
//!   fig11  per-layer activation-vs-weight truncation     [logs]
//!   gradstab  stable vs naive SVD backward norms         [logs]
//!
//!   cargo bench --bench bench_training_analysis -- fig7 fig8 ...

use dobi::bench::{artifacts_available, artifacts_dir, fmt_f, Table};
use dobi::config::Manifest;
use dobi::json::Json;

fn main() {
    if !artifacts_available() {
        eprintln!("[bench_training_analysis] artifacts not built — run `make artifacts`");
        return;
    }
    let filter: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let want = |name: &str| filter.is_empty() || filter.iter().any(|f| f == name);
    let m = Manifest::load(&artifacts_dir()).expect("manifest");

    if want("fig3a") { fig3a(&m); }
    if want("fig3b") { fig3b(&m); }
    if want("fig3c") { fig3c(&m); }
    if want("fig7") { fig7(&m); }
    if want("fig8") { fig8(&m); }
    if want("fig11") { fig11(&m); }
    if want("gradstab") { gradstab(&m); }
}

fn series(j: &Json) -> Vec<f64> {
    j.as_arr()
        .map(|a| a.iter().filter_map(Json::as_f64).collect())
        .unwrap_or_default()
}

fn sparkline(xs: &[f64]) -> String {
    if xs.is_empty() {
        return String::new();
    }
    let (lo, hi) = xs.iter().fold((f64::MAX, f64::MIN), |(l, h), &x| (l.min(x), h.max(x)));
    let glyphs = ['_', '.', ':', '-', '=', '+', '*', '#'];
    xs.iter()
        .map(|&x| {
            let t = if hi > lo { (x - lo) / (hi - lo) } else { 0.5 };
            glyphs[((t * 7.0).round() as usize).min(7)]
        })
        .collect()
}

fn fig3a(m: &Manifest) {
    let Some(a) = m.analysis.get("fig3a") else {
        println!("[fig3a] not in manifest (quick profile)");
        return;
    };
    let dense = a.get("dense_ppl").and_then(Json::as_f64).unwrap_or(f64::NAN);
    let mut t = Table::new("Fig 3a — guided truncation: val PPL during k-training (ratio 0.85)",
                           &["setting", "start", "end", "vs dense", "trace"]);
    for key in ["single", "multi"] {
        let Some(s) = a.get(key) else { continue };
        let ppl = series(s.get("val_ppl").unwrap_or(&Json::Null));
        if ppl.is_empty() {
            continue;
        }
        t.row(vec![
            format!("{key}-layer"),
            fmt_f(ppl[0], 3),
            fmt_f(*ppl.last().unwrap(), 3),
            fmt_f(ppl.last().unwrap() - dense, 3),
            sparkline(&ppl),
        ]);
    }
    t.print();
    println!("paper shape: truncating only late layers can even IMPROVE on dense\n\
              (negative 'vs dense'), single-layer >= multi-layer.");
}

fn fig3b(m: &Manifest) {
    let Some(a) = m.analysis.get("fig3b") else {
        println!("[fig3b] not in manifest (quick profile)");
        return;
    };
    let mut t = Table::new("Fig 3b — k-training with large vs small batch (ratio 0.6)",
                           &["batch", "final val PPL", "val trace"]);
    for key in ["batch8", "batch2"] {
        let Some(s) = a.get(key) else { continue };
        let ppl = series(s.get("val_ppl").unwrap_or(&Json::Null));
        if ppl.is_empty() {
            continue;
        }
        t.row(vec![key.into(), fmt_f(*ppl.last().unwrap(), 3), sparkline(&ppl)]);
    }
    t.print();
    println!("paper shape: small-batch training lands within noise of large-batch\n\
              (the 224-parameter optimization is sample-efficient).");
}

fn fig3c(m: &Manifest) {
    let Some(a) = m.analysis.get("fig3c") else { return };
    let dims = series(a.get("dims").unwrap_or(&Json::Null));
    let pca = series(a.get("pca_bytes").unwrap_or(&Json::Null));
    let ipca = series(a.get("ipca_bytes").unwrap_or(&Json::Null));
    let mut t = Table::new("Fig 3c — PCA vs IPCA peak memory for n x n targets (8 batches)",
                           &["n", "PCA MB", "IPCA MB", "ratio"]);
    for i in 0..dims.len() {
        t.row(vec![
            format!("{}", dims[i] as usize),
            fmt_f(pca[i] / 1e6, 2),
            fmt_f(ipca[i] / 1e6, 2),
            format!("{:.0}x", pca[i] / ipca[i]),
        ]);
    }
    t.print();
    if let (Some(d), Some(peak)) = (
        a.get("subspace_distance").and_then(Json::as_f64),
        a.get("ipca_peak_bytes_measured").and_then(Json::as_f64),
    ) {
        println!("measured: IPCA/full-PCA subspace distance {d:.4} (agreement), \
                  measured IPCA peak {:.2} MB", peak / 1e6);
    }
    println!("paper shape: PCA grows with batch count & dimension (exponential-looking\n\
              blow-up in Fig 3c), IPCA stays ~constant.");
}

fn fig7(m: &Manifest) {
    let Some(kt) = m.training.path("llama-nano.ktrain") else { return };
    let Some(obj) = kt.as_obj() else { return };
    let mut t = Table::new("Fig 7 — k-training loss & val PPL vs step (llama-nano)",
                           &["ratio", "loss start->end", "loss trace", "val ppl trace"]);
    for (ratio, log) in obj {
        let loss = series(log.get("loss_history").unwrap_or(&Json::Null));
        let ppl = series(log.get("val_ppl_history").unwrap_or(&Json::Null));
        if loss.is_empty() {
            continue;
        }
        t.row(vec![
            ratio.clone(),
            format!("{:.3} -> {:.3}", loss[0], loss.last().unwrap()),
            sparkline(&loss),
            sparkline(&ppl),
        ]);
    }
    t.print();
    println!("paper shape: both curves decrease — the differentiable truncation\n\
              genuinely optimizes the positions.");
}

fn fig8(m: &Manifest) {
    let Some(kt) = m.training.path("llama-nano.ktrain") else { return };
    let Some(obj) = kt.as_obj() else { return };
    for (ratio, log) in obj {
        let names: Vec<String> = log
            .get("target_names")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
            .unwrap_or_default();
        let hist = log.get("k_history").and_then(Json::as_arr);
        let Some(hist) = hist else { continue };
        if hist.is_empty() || names.is_empty() {
            continue;
        }
        let first = series(&hist[0]);
        let last = series(hist.last().unwrap());
        let mut t = Table::new(
            &format!("Figs 8/9/10 — k evolution per matrix (ratio {ratio})"),
            &["matrix", "k start", "k end", "drift"],
        );
        // aggregate by matrix kind and by layer for readability
        let mut by_kind: std::collections::BTreeMap<&str, (f64, f64, usize)> = Default::default();
        let mut by_layer: std::collections::BTreeMap<String, (f64, f64, usize)> = Default::default();
        for (i, n) in names.iter().enumerate() {
            let kind = n.rsplit('.').next().unwrap_or(n);
            let layer = n.split('.').nth(1).unwrap_or("?").to_string();
            let e = by_kind.entry(kind).or_insert((0.0, 0.0, 0));
            e.0 += first[i];
            e.1 += last[i];
            e.2 += 1;
            let e2 = by_layer.entry(format!("layer {layer}")).or_insert((0.0, 0.0, 0));
            e2.0 += first[i];
            e2.1 += last[i];
            e2.2 += 1;
        }
        let mut rows: Vec<(String, (f64, f64, usize))> =
            by_kind.iter().map(|(k, v)| (k.to_string(), *v)).collect();
        rows.extend(by_layer.iter().map(|(k, v)| (k.clone(), *v)));
        for (kind, (f, l, c)) in rows {
            let fs = f / c as f64;
            let ls = l / c as f64;
            t.row(vec![kind, fmt_f(fs, 1), fmt_f(ls, 1), format!("{:+.1}", ls - fs)]);
        }
        t.print();
    }
    println!("paper shape: wq/wk drift DOWN (attention tolerates low rank), w_down/wv\n\
              drift UP; later layers accept more truncation than early ones.");
}

fn fig11(m: &Manifest) {
    let Some(a) = m.analysis.get("fig11") else { return };
    let Some(arr) = a.as_arr() else { return };
    let mut t = Table::new(
        "Fig 11 / A.10 — per-layer truncation: activations vs weights (PPL)",
        &["layer", "k", "activation", "weight", "act wins"],
    );
    for e in arr {
        let pa = e.f64_of("activation");
        let pw = e.f64_of("weight");
        t.row(vec![
            format!("{}", e.usize_of("layer")),
            format!("{}", e.usize_of("k")),
            fmt_f(pa, 2),
            fmt_f(pw, 2),
            format!("{}", pa <= pw),
        ]);
    }
    t.print();
    println!("paper shape: activation truncation <= weight truncation at every (layer, k).");
}

fn gradstab(m: &Manifest) {
    let Some(g) = m.analysis.get("gradstab") else { return };
    let mut t = Table::new(
        "Gradient stabilization ablation — SVD backward on a degenerate activation",
        &["backward", "grad norm", "finite"],
    );
    t.row(vec![
        "stabilized (Taylor + clamp)".into(),
        format!("{:.4}", g.get("stable_norm").and_then(Json::as_f64).unwrap_or(f64::NAN)),
        format!("{}", g.get("stable_finite").and_then(Json::as_bool).unwrap_or(false)),
    ]);
    let naive = g.get("naive_norm").and_then(Json::as_f64);
    t.row(vec![
        "naive 1/(s_j^2 - s_i^2)".into(),
        naive.map(|x| format!("{x:.3e}")).unwrap_or_else(|| "NaN/Inf".into()),
        format!("{}", g.get("naive_finite").and_then(Json::as_bool).unwrap_or(false)),
    ]);
    t.print();
    println!("paper claim (Eq. 1-2): the naive rule explodes exactly where LLM\n\
              activations live (near-degenerate spectra); the Taylor form stays finite.");
}
