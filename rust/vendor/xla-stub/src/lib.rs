//! Compile-time stand-in for the `xla` crate (xla_extension PJRT bindings).
//!
//! The PJRT/XLA native library is not available in offline build
//! environments, so this crate provides the exact API surface the `dobi`
//! runtime uses — same type and method names — with every constructor
//! returning a descriptive error.  The PJRT execution path therefore
//! *compiles* everywhere and *fails cleanly at runtime*, and the serving
//! stack falls back to the native low-rank backend (see
//! `dobi::runtime::make_backend`).
//!
//! To run against real PJRT, point the `xla` dependency in
//! `rust/Cargo.toml` at the actual `xla` bindings instead of this stub; no
//! source change is required anywhere else.

use std::fmt;

const UNAVAILABLE: &str = "PJRT unavailable: built against the in-tree `xla-stub` crate \
     (no XLA native library in this environment); use the native low-rank backend \
     (--backend native) or link the real `xla` bindings in rust/Cargo.toml";

/// Error type standing in for `xla::Error`.  Implements `std::error::Error`
/// so `anyhow`-style `?`/`.context(..)` conversions work unchanged.
pub struct Error(pub String);

impl Error {
    fn unavailable() -> Error {
        Error(UNAVAILABLE.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(Error::unavailable())
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }
}

pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<std::path::Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

pub struct PjRtBuffer {
    client: PjRtClient,
}

impl PjRtBuffer {
    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_fail_with_guidance() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("--backend native"), "{e}");
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0; 4])
            .is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo.txt").is_err());
    }
}
