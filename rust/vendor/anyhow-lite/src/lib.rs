//! Minimal, dependency-free subset of the `anyhow` error-handling API.
//!
//! The build environments this repo targets have no crates.io access, so
//! the workspace vendors the small slice of `anyhow` the codebase uses:
//! [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros.  The crate is dependency-renamed
//! to `anyhow` in `rust/Cargo.toml`, so swapping in the real crate (when a
//! registry is available) is a one-line change and no source edits.
//!
//! Semantics mirror `anyhow` where the repo relies on them:
//! * `Display` prints the outermost message; `{:#}` prints the whole
//!   context chain joined by `: `; `Debug` prints a `Caused by:` list.
//! * `?` converts any `std::error::Error + Send + Sync + 'static`.
//! * `.context(..)` / `.with_context(..)` wrap both foreign errors and
//!   existing [`Error`]s (hence the `E: Into<Error>` bound).

use std::fmt;

/// Error with a context chain.  `chain[0]` is the outermost (most recently
/// attached) message, matching `anyhow`'s display order.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { chain: vec![msg.to_string()] }
    }

    /// Attach an outer context message (consuming form used by [`Context`]).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Outermost-to-innermost messages.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like `anyhow`, `Error` deliberately does NOT implement `std::error::Error`
// so this blanket conversion can coexist with `impl From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                "condition failed: {}", ::std::stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("loading weights");
        assert_eq!(format!("{e}"), "loading weights");
        assert_eq!(format!("{e:#}"), "loading weights: disk on fire");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e: Error = Error::msg("inner").context("outer");
        let d = format!("{e:?}");
        assert!(d.starts_with("outer"), "{d}");
        assert!(d.contains("Caused by:"), "{d}");
        assert!(d.contains("inner"), "{d}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "disk on fire");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("phase 1").unwrap_err();
        assert_eq!(e.to_string(), "phase 1");
        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
        // context on an already-anyhow Result (the E = Error case)
        let r2: Result<()> = Err(Error::msg("base"));
        let e2 = r2.with_context(|| format!("step {}", 2)).unwrap_err();
        assert_eq!(format!("{e2:#}"), "step 2: base");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).unwrap_err().to_string().contains("12"));
        assert!(f(7).unwrap_err().to_string().contains("unlucky 7"));
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }
}
